//! Miniature property-based testing framework (proptest is unavailable in
//! the offline vendor set).
//!
//! `Gen` wraps a seeded PCG32 with convenience generators; [`forall`] runs a
//! property over many random cases and, on failure, retries with a simple
//! halving shrink over the size hint, reporting the seed so any failure is
//! reproducible with `FTSZ_PROP_SEED=<seed> cargo test`.

use crate::util::rng::{Pcg32, SplitMix64};

/// Random case generator handed to properties.
pub struct Gen {
    rng: Pcg32,
    /// Current size hint; shrink passes reduce it.
    pub size: usize,
}

impl Gen {
    /// New generator for one case.
    pub fn new(seed: u64, size: usize) -> Self {
        Self { rng: Pcg32::new(seed), size }
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.rng.index(hi - lo + 1)
    }

    /// Uniform u64.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform u32.
    pub fn u32(&mut self) -> u32 {
        self.rng.next_u32()
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Finite f32 from a mix of uniform, exponent-stratified and special
    /// values — good coverage of the float space without NaN/Inf.
    pub fn f32_finite(&mut self) -> f32 {
        match self.rng.index(10) {
            0 => 0.0,
            1 => -0.0,
            2 => 1.0,
            3 => -1.0,
            4 => f32::MIN_POSITIVE,
            _ => {
                let exp = self.rng.index(41) as i32 - 20; // 1e-20 .. 1e20
                let mant = self.rng.range_f64(-1.0, 1.0);
                (mant * 10f64.powi(exp)) as f32
            }
        }
    }

    /// Vector of finite f32s sized by the current size hint.
    pub fn vec_f32(&mut self, max_len: usize) -> Vec<f32> {
        let len = self.usize_in(1, max_len.min(self.size.max(1)));
        (0..len).map(|_| self.f32_finite()).collect()
    }

    /// Vector of smooth f32s (random walk) — compressible data.
    pub fn vec_f32_smooth(&mut self, max_len: usize) -> Vec<f32> {
        let len = self.usize_in(1, max_len.min(self.size.max(1)));
        let mut v = Vec::with_capacity(len);
        let mut x = self.rng.range_f64(-1.0, 1.0);
        for _ in 0..len {
            x += self.rng.range_f64(-0.01, 0.01);
            v.push(x as f32);
        }
        v
    }

    /// Pick one of the provided items.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.index(items.len())]
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }
}

/// Outcome of a property run.
pub struct PropResult {
    /// Seed of the failing case, if any.
    pub failure: Option<(u64, String)>,
    /// Cases executed.
    pub cases: usize,
}

/// Run `prop` over `cases` random cases. The property returns
/// `Err(description)` to signal failure. Panics (like assert!) are treated
/// as failures too, with the seed reported.
pub fn forall<P>(name: &str, cases: usize, prop: P)
where
    P: Fn(&mut Gen) -> Result<(), String> + std::panic::RefUnwindSafe,
{
    let base_seed = std::env::var("FTSZ_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xf7c3_5eed);
    let mut expander = SplitMix64::new(base_seed);
    for case in 0..cases {
        let seed = expander.next_u64();
        let run = |size: usize| -> Result<(), String> {
            let mut g = Gen::new(seed, size);
            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g))) {
                Ok(r) => r,
                Err(p) => {
                    let msg = p
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| p.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "panic".into());
                    Err(format!("panicked: {msg}"))
                }
            }
        };
        if let Err(msg) = run(64) {
            // shrink: retry with smaller size hints, keep the smallest failure
            let mut final_msg = msg;
            let mut final_size = 64usize;
            let mut size = 32usize;
            while size >= 1 {
                if let Err(m) = run(size) {
                    final_msg = m;
                    final_size = size;
                }
                if size == 1 {
                    break;
                }
                size /= 2;
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed}, size {final_size}): {final_msg}\n\
                 reproduce with FTSZ_PROP_SEED={base_seed}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivially() {
        forall("trivial", 50, |g| {
            let v = g.usize_in(1, 10);
            if (1..=10).contains(&v) { Ok(()) } else { Err(format!("{v} out of range")) }
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn forall_reports_failures() {
        forall("fails", 10, |g| {
            if g.u64() % 2 == 0 || g.u64() % 2 == 1 {
                Err("always".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    #[should_panic(expected = "panicked")]
    fn forall_catches_panics() {
        forall("panics", 3, |_| -> Result<(), String> { panic!("boom") });
    }

    #[test]
    fn generators_are_seed_deterministic() {
        let mut a = Gen::new(42, 64);
        let mut b = Gen::new(42, 64);
        assert_eq!(a.vec_f32(32), b.vec_f32(32));
    }

    #[test]
    fn smooth_vectors_are_smooth() {
        let mut g = Gen::new(7, 64);
        let v = g.vec_f32_smooth(64);
        for w in v.windows(2) {
            assert!((w[1] - w[0]).abs() <= 0.02);
        }
    }
}
