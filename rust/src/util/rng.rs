//! Deterministic PRNGs (PCG32 / SplitMix64), built from scratch.
//!
//! Everything random in this repo — synthetic datasets, fault injection,
//! property tests — flows through these generators with explicit seeds, so
//! every experiment in EXPERIMENTS.md is exactly reproducible.

/// PCG-XSH-RR 64/32 (O'Neill 2014). Small state, excellent statistical
/// quality, and `stream` support for decorrelated parallel generators.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed, on the default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Create a generator on an explicit stream; distinct streams yield
    /// statistically independent sequences for the same seed.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.inc.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Next uniform `u32`.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next uniform `u64`.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform `usize` index in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform `f64` in `[0, 1)`, 53 bits of entropy.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (cached second sample omitted for
    /// determinism simplicity; this is not a hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 1e-300 {
                let v = self.f64();
                return (-2.0 * u.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fork a decorrelated child generator (new stream derived from state).
    pub fn fork(&mut self) -> Pcg32 {
        let seed = self.next_u64();
        let stream = self.next_u64();
        Pcg32::with_stream(seed, stream)
    }
}

/// SplitMix64 — used to expand one user seed into many independent seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// New expander from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next expanded seed.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(1);
        let mut c = Pcg32::new(2);
        let va: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        let vc: Vec<u32> = (0..8).map(|_| c.next_u32()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg32::with_stream(7, 1);
        let mut b = Pcg32::with_stream(7, 2);
        let va: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg32::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut rng = Pcg32::new(9);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn fork_decorrelates() {
        let mut parent = Pcg32::new(5);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let v1: Vec<u32> = (0..8).map(|_| c1.next_u32()).collect();
        let v2: Vec<u32> = (0..8).map(|_| c2.next_u32()).collect();
        assert_ne!(v1, v2);
    }

    #[test]
    fn splitmix_expands_distinct() {
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
    }
}
