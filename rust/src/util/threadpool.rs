//! Minimal scoped thread pool + parallel-for (tokio/rayon are unavailable
//! offline; `crossbeam_utils::thread::scope` provides safe borrowing).
//!
//! This is the execution substrate of the [`crate::coordinator`]: bounded
//! work queues with backpressure, deterministic chunk assignment for
//! reproducible experiments.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

/// Run `f(chunk_index, item_index_range)` over `n_items` split into
/// contiguous chunks, one chunk stream per worker, work-stealing by atomic
/// counter. Results are written by the caller through interior mutability
/// or per-chunk output vectors.
pub fn parallel_chunks<F>(n_items: usize, chunk: usize, workers: usize, f: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    assert!(chunk > 0);
    let n_chunks = n_items.div_ceil(chunk);
    let workers = workers.max(1).min(n_chunks.max(1));
    let next = AtomicUsize::new(0);
    crossbeam_utils::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|_| loop {
                let c = next.fetch_add(1, Ordering::Relaxed);
                if c >= n_chunks {
                    break;
                }
                let lo = c * chunk;
                let hi = (lo + chunk).min(n_items);
                f(c, lo..hi);
            });
        }
    })
    .expect("worker panicked");
}

/// Map `f` over `0..n` in parallel, collecting results in order.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        let workers = workers.max(1).min(n.max(1));
        crossbeam_utils::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|_| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let v = f(i);
                    *slots[i].lock().unwrap() = Some(v);
                });
            }
        })
        .expect("worker panicked");
        for (i, slot) in slots.into_iter().enumerate() {
            out[i] = slot.into_inner().unwrap().unwrap();
        }
    }
    out
}

/// A bounded MPMC channel built on Mutex+Condvar — the backpressure
/// primitive for the streaming pipeline (send blocks when full).
pub struct BoundedQueue<T> {
    inner: Mutex<QueueInner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

struct QueueInner<T> {
    items: std::collections::VecDeque<T>,
    closed: bool,
}

impl<T> BoundedQueue<T> {
    /// New queue holding at most `capacity` items.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            inner: Mutex::new(QueueInner { items: std::collections::VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    /// Blocking push; returns `false` if the queue was closed.
    pub fn push(&self, item: T) -> bool {
        let mut g = self.inner.lock().unwrap();
        while g.items.len() >= self.capacity && !g.closed {
            g = self.not_full.wait(g).unwrap();
        }
        if g.closed {
            return false;
        }
        g.items.push_back(item);
        self.not_empty.notify_one();
        true
    }

    /// Blocking pop; `None` once closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(v) = g.items.pop_front() {
                self.not_full.notify_one();
                return Some(v);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Close: pending pops drain, new pushes fail.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_chunks_covers_everything_once() {
        let n = 1003;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_chunks(n, 64, 4, |_, range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_map_ordered() {
        let out = parallel_map(100, 8, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn queue_fifo_and_close() {
        let q = BoundedQueue::new(4);
        assert!(q.push(1));
        assert!(q.push(2));
        assert_eq!(q.pop(), Some(1));
        q.close();
        assert!(!q.push(3));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn queue_backpressure_blocks_until_pop() {
        let q = std::sync::Arc::new(BoundedQueue::new(1));
        q.push(0u64);
        let q2 = q.clone();
        let pushed = std::sync::Arc::new(AtomicU64::new(0));
        let p2 = pushed.clone();
        let h = std::thread::spawn(move || {
            q2.push(1);
            p2.store(1, Ordering::SeqCst);
        });
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert_eq!(pushed.load(Ordering::SeqCst), 0, "push must block while full");
        assert_eq!(q.pop(), Some(0));
        h.join().unwrap();
        assert_eq!(pushed.load(Ordering::SeqCst), 1);
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn queue_many_producers_consumers() {
        let q = std::sync::Arc::new(BoundedQueue::new(8));
        let total = std::sync::Arc::new(AtomicU64::new(0));
        crossbeam_utils::thread::scope(|s| {
            for t in 0..4 {
                let q = q.clone();
                s.spawn(move |_| {
                    for i in 0..100u64 {
                        q.push(t * 100 + i);
                    }
                });
            }
            for _ in 0..4 {
                let q = q.clone();
                let total = total.clone();
                s.spawn(move |_| {
                    while let Some(v) = q.pop() {
                        total.fetch_add(v, Ordering::Relaxed);
                    }
                });
            }
            s.spawn(|_| {
                // closing after producers finish is racy in this toy test;
                // give producers time then close.
                std::thread::sleep(std::time::Duration::from_millis(300));
                q.close();
            });
        })
        .unwrap();
        let expect: u64 = (0..400u64).sum();
        assert_eq!(total.load(Ordering::SeqCst), expect);
    }
}
