//! Minimal scoped thread pool + parallel-for (tokio/rayon are unavailable
//! offline; `std::thread::scope` provides safe borrowing with no external
//! dependency).
//!
//! This is the execution substrate of the [`crate::coordinator`] and of the
//! block-parallel compression core ([`crate::compressor::engine`]): bounded
//! work queues with backpressure, deterministic result ordering for
//! byte-identical archives and reproducible experiments.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

/// Run `f(chunk_index, item_index_range)` over `n_items` split into
/// contiguous chunks, one chunk stream per worker, work-stealing by atomic
/// counter. Results are written by the caller through interior mutability
/// or per-chunk output vectors.
pub fn parallel_chunks<F>(n_items: usize, chunk: usize, workers: usize, f: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    assert!(chunk > 0);
    let n_chunks = n_items.div_ceil(chunk);
    let workers = workers.max(1).min(n_chunks.max(1));
    if workers <= 1 {
        for c in 0..n_chunks {
            let lo = c * chunk;
            f(c, lo..(lo + chunk).min(n_items));
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let c = next.fetch_add(1, Ordering::Relaxed);
                if c >= n_chunks {
                    break;
                }
                let lo = c * chunk;
                let hi = (lo + chunk).min(n_items);
                f(c, lo..hi);
            });
        }
    });
}

/// Map `f` over `0..n` in parallel, collecting results **in index order**
/// regardless of completion order — the property the block-parallel engine
/// relies on for byte-identical archives. `workers <= 1` (or `n <= 1`)
/// runs inline with zero thread overhead, so the sequential path really is
/// the 1-worker path.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 {
        return (0..n).map(f).collect();
    }
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                *slots[i].lock().unwrap() = Some(v);
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| slot.into_inner().unwrap().expect("worker filled every slot"))
        .collect()
}

/// A bounded MPMC channel built on Mutex+Condvar — the backpressure
/// primitive for the streaming pipeline (send blocks when full).
pub struct BoundedQueue<T> {
    inner: Mutex<QueueInner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
    /// Pushes that actually blocked on a full queue. Counted here, under
    /// the queue lock, because any check made *before* calling `push`
    /// races with concurrent pops/pushes and under/over-counts.
    blocked_pushes: AtomicU64,
}

struct QueueInner<T> {
    items: std::collections::VecDeque<T>,
    closed: bool,
}

impl<T> BoundedQueue<T> {
    /// New queue holding at most `capacity` items.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            inner: Mutex::new(QueueInner { items: std::collections::VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
            blocked_pushes: AtomicU64::new(0),
        }
    }

    /// Blocking push; returns `false` if the queue was closed.
    pub fn push(&self, item: T) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.items.len() >= self.capacity && !g.closed {
            // count each push that really blocks, exactly once
            self.blocked_pushes.fetch_add(1, Ordering::Relaxed);
            while g.items.len() >= self.capacity && !g.closed {
                g = self.not_full.wait(g).unwrap();
            }
        }
        if g.closed {
            return false;
        }
        g.items.push_back(item);
        self.not_empty.notify_one();
        true
    }

    /// Blocking pop; `None` once closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(v) = g.items.pop_front() {
                self.not_full.notify_one();
                return Some(v);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Close: pending pops drain, new pushes fail.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Pushes that blocked on a full queue so far (backpressure events).
    pub fn blocked_pushes(&self) -> u64 {
        self.blocked_pushes.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_chunks_covers_everything_once() {
        let n = 1003;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_chunks(n, 64, 4, |_, range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_map_ordered() {
        let out = parallel_map(100, 8, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn parallel_map_accepts_non_default_non_clone_types() {
        // the engine maps blocks to big result structs that are neither
        // Default nor Clone; the pool must not require either
        struct Big(Vec<u32>);
        let out = parallel_map(17, 4, |i| Big(vec![i as u32; i + 1]));
        for (i, b) in out.iter().enumerate() {
            assert_eq!(b.0.len(), i + 1);
        }
    }

    #[test]
    fn parallel_map_single_worker_runs_inline() {
        // must work from within an active thread (nested parallelism)
        let out = parallel_map(4, 1, |i| parallel_map(3, 2, move |j| i * 3 + j));
        assert_eq!(out[2], vec![6, 7, 8]);
    }

    #[test]
    fn queue_fifo_and_close() {
        let q = BoundedQueue::new(4);
        assert!(q.push(1));
        assert!(q.push(2));
        assert_eq!(q.pop(), Some(1));
        q.close();
        assert!(!q.push(3));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn queue_backpressure_blocks_until_pop() {
        let q = std::sync::Arc::new(BoundedQueue::new(1));
        q.push(0u64);
        let q2 = q.clone();
        let pushed = std::sync::Arc::new(AtomicU64::new(0));
        let p2 = pushed.clone();
        let h = std::thread::spawn(move || {
            q2.push(1);
            p2.store(1, Ordering::SeqCst);
        });
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert_eq!(pushed.load(Ordering::SeqCst), 0, "push must block while full");
        assert_eq!(q.pop(), Some(0));
        h.join().unwrap();
        assert_eq!(pushed.load(Ordering::SeqCst), 1);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.blocked_pushes(), 1, "exactly one push blocked");
    }

    #[test]
    fn blocked_push_count_is_exact_single_threaded() {
        // non-blocking pushes must not count
        let q = BoundedQueue::new(8);
        for i in 0..8 {
            assert!(q.push(i));
        }
        assert_eq!(q.blocked_pushes(), 0);
    }

    #[test]
    fn blocked_push_count_matches_forced_blocks() {
        // capacity 1, producer pushes N items while a slow consumer pops:
        // every push after the first finds the queue full and must block
        let n = 50u64;
        let q = std::sync::Arc::new(BoundedQueue::new(1));
        let qp = q.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..n {
                qp.push(i);
            }
            qp.close();
        });
        let mut got = Vec::new();
        while let Some(v) = q.pop() {
            std::thread::sleep(std::time::Duration::from_micros(200));
            got.push(v);
        }
        producer.join().unwrap();
        assert_eq!(got.len(), n as usize);
        // at least the steady-state pushes blocked; never more than n
        let blocked = q.blocked_pushes();
        assert!(blocked <= n, "blocked {blocked} > pushes {n}");
        assert!(blocked >= n / 2, "expected most pushes to block, got {blocked}");
    }

    #[test]
    fn queue_many_producers_consumers() {
        let q = std::sync::Arc::new(BoundedQueue::new(8));
        let total = std::sync::Arc::new(AtomicU64::new(0));
        std::thread::scope(|s| {
            for t in 0..4 {
                let q = q.clone();
                s.spawn(move || {
                    for i in 0..100u64 {
                        q.push(t * 100 + i);
                    }
                });
            }
            for _ in 0..4 {
                let q = q.clone();
                let total = total.clone();
                s.spawn(move || {
                    while let Some(v) = q.pop() {
                        total.fetch_add(v, Ordering::Relaxed);
                    }
                });
            }
            s.spawn(|| {
                // closing after producers finish is racy in this toy test;
                // give producers time then close.
                std::thread::sleep(std::time::Duration::from_millis(300));
                q.close();
            });
        });
        let expect: u64 = (0..400u64).sum();
        assert_eq!(total.load(Ordering::SeqCst), expect);
    }
}
