//! Timing + micro-bench harness (criterion is unavailable offline).
//!
//! `bench_fn` measures a closure with warmup, repetitions, and robust
//! statistics; the bench binaries under `rust/benches/` print paper-style
//! tables using these primitives.

use std::time::{Duration, Instant};

/// Simple scoped stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    /// Start timing now.
    pub fn new() -> Self {
        Self { start: Instant::now() }
    }

    /// Elapsed seconds.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed duration.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Restart and return the lap time in seconds.
    pub fn lap(&mut self) -> f64 {
        let s = self.secs();
        self.start = Instant::now();
        s
    }
}

/// Summary statistics of repeated timing samples (seconds).
#[derive(Debug, Clone)]
pub struct BenchStats {
    /// Per-iteration samples, sorted ascending.
    pub samples: Vec<f64>,
}

impl BenchStats {
    /// Build from raw samples.
    pub fn from_samples(mut samples: Vec<f64>) -> Self {
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self { samples }
    }

    /// Minimum sample.
    pub fn min(&self) -> f64 {
        *self.samples.first().unwrap_or(&f64::NAN)
    }

    /// Median sample.
    pub fn median(&self) -> f64 {
        let n = self.samples.len();
        if n == 0 {
            return f64::NAN;
        }
        if n % 2 == 1 { self.samples[n / 2] } else { 0.5 * (self.samples[n / 2 - 1] + self.samples[n / 2]) }
    }

    /// Arithmetic mean.
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }
}

/// Measure `f` with `warmup` unrecorded runs then `reps` recorded runs.
pub fn bench_fn<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    BenchStats::from_samples(samples)
}

/// Pretty seconds: picks ns/µs/ms/s.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let st = BenchStats::from_samples(vec![3.0, 1.0, 2.0]);
        assert_eq!(st.min(), 1.0);
        assert_eq!(st.median(), 2.0);
        assert!((st.mean() - 2.0).abs() < 1e-12);
        assert!((st.stddev() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn even_median() {
        let st = BenchStats::from_samples(vec![1.0, 2.0, 3.0, 4.0]);
        assert!((st.median() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn bench_counts_reps() {
        let mut calls = 0usize;
        let st = bench_fn(2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(st.samples.len(), 5);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(5e-10).ends_with("ns"));
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }
}
