//! Coordinator invariants: pipeline completeness/ordering under stress,
//! sharding partition properties, weak-scaling model sanity.

use ftsz::compressor::{CompressionConfig, ErrorBound};
use ftsz::coordinator::sharding::{balanced, rebalance, round_robin, Shard};
use ftsz::coordinator::{run_pipeline, WorkItem};
use ftsz::data::{synthetic, Dims};
use ftsz::ft;
use ftsz::inject::Engine;
use ftsz::util::prop::forall;

fn items_of(n: usize, edge: usize) -> Vec<WorkItem> {
    (0..n)
        .map(|i| {
            let f = synthetic::hurricane_field(
                "t",
                Dims::d3(edge.max(2) / 2, edge, edge),
                i as u64,
            );
            WorkItem { id: i, dims: f.dims, data: f.data }
        })
        .collect()
}

#[test]
fn pipeline_prop_complete_ordered_correct() {
    forall("pipeline completeness/order", 12, |g| {
        let n = g.usize_in(1, 20);
        let workers = g.usize_in(1, 8);
        let depth = g.usize_in(1, 6);
        let edge = [8usize, 12, 16][g.usize_in(0, 2)];
        let items = items_of(n, edge);
        let originals: Vec<Vec<f32>> = items.iter().map(|i| i.data.clone()).collect();
        let cfg = CompressionConfig::new(ErrorBound::Abs(1e-3)).with_block_size(6);
        let out = run_pipeline(items, Engine::FaultTolerant, &cfg, workers, depth)
            .map_err(|e| e.to_string())?;
        if out.archives.len() != n {
            return Err(format!("dropped items: {} of {n}", out.archives.len()));
        }
        for (i, (id, bytes)) in out.archives.iter().enumerate() {
            if *id != i {
                return Err(format!("order broken at {i}: id {id}"));
            }
            let dec = ft::decompress(bytes).map_err(|e| e.to_string())?;
            let max = ftsz::analysis::max_abs_err(&originals[i], &dec.data);
            if max > 1e-3 {
                return Err(format!("item {i} bound violated: {max}"));
            }
        }
        Ok(())
    });
}

#[test]
fn pipeline_oversubscribed_workers() {
    // more workers than items, deep queue: must not deadlock or drop
    let items = items_of(3, 10);
    let cfg = CompressionConfig::new(ErrorBound::Abs(1e-3));
    let out = run_pipeline(items, Engine::RandomAccess, &cfg, 16, 32).unwrap();
    assert_eq!(out.archives.len(), 3);
}

#[test]
fn pipeline_depth_one_backpressure() {
    // queue depth 1 forces full backpressure serialization; still complete
    let items = items_of(10, 10);
    let cfg = CompressionConfig::new(ErrorBound::Abs(1e-3));
    let out = run_pipeline(items, Engine::Classic, &cfg, 2, 1).unwrap();
    assert_eq!(out.archives.len(), 10);
    assert_eq!(out.metrics.items_out.load(std::sync::atomic::Ordering::Relaxed), 10);
}

#[test]
fn sharding_props() {
    forall("sharding partition + balance", 60, |g| {
        let n_shards = g.usize_in(0, 60);
        let n_ranks = g.usize_in(1, 16);
        let shards: Vec<Shard> =
            (0..n_shards).map(|id| Shard { id, weight: 1 + g.u64() % 1000 }).collect();
        for a in [round_robin(&shards, n_ranks), balanced(&shards, n_ranks)] {
            if !a.is_partition(&shards) {
                return Err("not a partition".into());
            }
            if a.ranks.len() != n_ranks {
                return Err("wrong rank count".into());
            }
        }
        // LPT bound: max load <= mean + max weight (classic greedy bound)
        let b = balanced(&shards, n_ranks);
        let loads = b.loads(&shards);
        let total: u64 = loads.iter().sum();
        let mean = total as f64 / n_ranks as f64;
        let wmax = shards.iter().map(|s| s.weight).max().unwrap_or(0) as f64;
        if *loads.iter().max().unwrap() as f64 > mean + wmax + 1e-9 {
            return Err(format!(
                "LPT bound violated: max {} mean {mean} wmax {wmax}",
                loads.iter().max().unwrap()
            ));
        }
        // rebalance to arbitrary new rank count stays a partition
        let r = rebalance(&b, &shards, g.usize_in(1, 16));
        if !r.is_partition(&shards) {
            return Err("rebalance broke the partition".into());
        }
        Ok(())
    });
}

#[test]
fn weak_scaling_monotone_in_ranks() {
    use ftsz::coordinator::weak_scaling_run;
    use ftsz::data::synthetic::Profile;
    use ftsz::io::SimulatedPfs;
    let cfg = CompressionConfig::new(ErrorBound::Rel(1e-3)).with_block_size(8);
    let pfs = SimulatedPfs::new(5e9, 1e-3);
    let mut last_write = 0.0;
    for ranks in [64usize, 256, 1024] {
        let p = weak_scaling_run(
            Engine::RandomAccess,
            Profile::Hurricane,
            16,
            ranks,
            1,
            &cfg,
            &pfs,
            3,
        )
        .unwrap();
        assert!(p.write_secs > last_write, "write time must grow with ranks");
        last_write = p.write_secs;
        assert!(p.ratio > 1.0);
    }
}

#[test]
fn metrics_backpressure_counted_under_slow_sink() {
    // tiny queue + many items: the producer must hit backpressure
    let items = items_of(16, 12);
    let cfg = CompressionConfig::new(ErrorBound::Abs(1e-3));
    let out = run_pipeline(items, Engine::RandomAccess, &cfg, 1, 1).unwrap();
    // not asserting a specific count (timing-dependent), only coherence
    assert_eq!(out.archives.len(), 16);
    assert!(out.metrics.ratio() >= 1.0);
}
