//! Cross-engine differential harness: every engine × worker count ×
//! archive format over one seeded corpus of awkward fields.
//!
//! The paper's independent-block model is engine-agnostic, and PR 5 made
//! that concrete with a fourth `BlockCodec`. The invariants every engine
//! must share — the ones this harness pins — are:
//!
//! * **round-trip within ε** for every corpus field;
//! * **byte-stable archives** across {1, 2, 4} workers (parallelism
//!   reorders computation, never the format);
//! * **clean reports agree**: a clean archive decodes with
//!   `DecompressReport::is_clean()` on every engine, through whichever
//!   reporting path the engine supports (verified decode for the ft
//!   engines, the reported-unverified path otherwise);
//! * all of the above in both **v1 and v2 (parity)** containers.
//!
//! Every assertion message is a minimized reproducer — `engine=… seed=…
//! shape=… field=… workers=… parity=…` — so a failure pastes straight
//! into a regression test.

use ftsz::analysis;
use ftsz::compressor::{classic, engine, CompressionConfig, ErrorBound, Parallelism};
use ftsz::data::{Dims, Field};
use ftsz::ft::parity::ParityParams;
use ftsz::ft::DecompressReport;
use ftsz::inject::Engine;
use ftsz::util::rng::Pcg32;

/// One corpus entry: a named, seeded field.
struct Case {
    kind: &'static str,
    seed: u64,
    dims: Dims,
    data: Vec<f32>,
}

impl Case {
    fn repro(&self, e: Engine, workers: usize, parity: bool) -> String {
        format!(
            "engine={} seed={} shape={:?} field={} workers={workers} parity={parity}",
            e.name(),
            self.seed,
            self.dims,
            self.kind
        )
    }
}

/// A smooth random-walk field (compresses well on every engine).
fn smooth(seed: u64, dims: Dims) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    let mut v = rng.range_f64(-5.0, 5.0);
    (0..dims.len())
        .map(|_| {
            v += rng.range_f64(-0.3, 0.3);
            v as f32
        })
        .collect()
}

/// White noise (compresses badly; exercises escape/unpredictable paths).
fn noisy(seed: u64, dims: Dims) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    (0..dims.len()).map(|_| rng.normal() as f32 * 10.0).collect()
}

/// Piecewise-constant plateaus with occasional spikes (exercises the xsz
/// constant-block detection next to wide-range blocks).
fn plateaus(seed: u64, dims: Dims) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    let mut level = 1.0f32;
    (0..dims.len())
        .map(|i| {
            if i % 97 == 0 {
                level = (rng.index(7) as f32) * 2.5;
            }
            if rng.index(211) == 0 {
                level * 1000.0 // spike
            } else {
                level
            }
        })
        .collect()
}

/// The seeded corpus: smooth / noisy / constant / plateau fields over
/// tiny, odd-shaped and regular grids. All values are finite (non-finite
/// round-trips are covered by per-engine unit tests; the differential
/// bound check needs comparable numerics).
fn corpus() -> Vec<Case> {
    let mut cases = Vec::new();
    let shapes = [
        Dims::d1(7),           // smaller than any block
        Dims::d1(500),         // rank-1
        Dims::d2(3, 5),        // odd rank-2
        Dims::d2(17, 23),      // awkward primes
        Dims::d3(1, 1, 9),     // degenerate axis
        Dims::d3(2, 3, 5),     // tiny odd cube
        Dims::d3(8, 10, 12),   // regular multi-block grid
    ];
    for (i, dims) in shapes.iter().enumerate() {
        let seed = 100 + i as u64;
        cases.push(Case { kind: "smooth", seed, dims: *dims, data: smooth(seed, *dims) });
    }
    // field variety on a mid-size grid
    let dims = Dims::d3(6, 10, 10);
    cases.push(Case { kind: "noisy", seed: 42, dims, data: noisy(42, dims) });
    cases.push(Case { kind: "constant", seed: 7, dims, data: vec![3.25; dims.len()] });
    cases.push(Case { kind: "plateaus", seed: 9, dims, data: plateaus(9, dims) });
    cases
}

/// The engine's natural reporting decode: verified (Algorithm 2) where
/// `sum_dc` exists, the reported-unverified path otherwise — every engine
/// has *some* path that surfaces the repair report.
fn report_of(e: Engine, bytes: &[u8]) -> Result<DecompressReport, ftsz::Error> {
    let codec = e.codec();
    if codec.supports_verify() {
        return codec.decompress_verified(bytes, Parallelism::Sequential).map(|(_, r)| r);
    }
    match e {
        Engine::Classic => classic::decompress_reported(bytes).map(|(_, r)| r),
        _ => engine::decompress_reported(bytes, Parallelism::Sequential).map(|(_, r)| r),
    }
}

#[test]
fn differential_all_engines_workers_and_formats() {
    let bound = 1e-3;
    for case in corpus() {
        for parity in [false, true] {
            let mut cfg =
                CompressionConfig::new(ErrorBound::Abs(bound)).with_block_size(4);
            if parity {
                cfg = cfg.with_archive_parity(ParityParams::xor(64, 8));
            }
            for e in Engine::ALL {
                let codec = e.codec();
                let base = codec
                    .compress(&case.data, case.dims, &cfg)
                    .unwrap_or_else(|err| {
                        panic!("{}: compress failed: {err}", case.repro(e, 1, parity))
                    });
                for workers in [1usize, 2, 4] {
                    // archives byte-stable across worker counts
                    let b = codec
                        .compress(&case.data, case.dims, &cfg.clone().with_workers(workers))
                        .unwrap_or_else(|err| {
                            panic!(
                                "{}: compress failed: {err}",
                                case.repro(e, workers, parity)
                            )
                        });
                    assert_eq!(
                        b,
                        base,
                        "{}: archive bytes differ from the 1-worker reference",
                        case.repro(e, workers, parity)
                    );
                    // round-trip within ε at every worker count
                    let dec = codec
                        .decompress(&base, Parallelism::from_workers(workers))
                        .unwrap_or_else(|err| {
                            panic!(
                                "{}: decompress failed: {err}",
                                case.repro(e, workers, parity)
                            )
                        });
                    assert_eq!(
                        dec.data.len(),
                        case.data.len(),
                        "{}: wrong output length",
                        case.repro(e, workers, parity)
                    );
                    let max = analysis::max_abs_err(&case.data, &dec.data);
                    assert!(
                        max <= bound,
                        "{}: bound violated ({max} > {bound})",
                        case.repro(e, workers, parity)
                    );
                }
                // clean archives report clean — and every engine agrees
                let report = report_of(e, &base).unwrap_or_else(|err| {
                    panic!("{}: reporting decode failed: {err}", case.repro(e, 1, parity))
                });
                assert!(
                    report.is_clean(),
                    "{}: clean archive reported events: {report:?}",
                    case.repro(e, 1, parity)
                );
            }
        }
    }
}

#[test]
fn differential_bitpack_mode_on_the_xsz_engines() {
    // --xsz-bitpack is format-visible (block tag 6) but must preserve
    // every cross-engine invariant on the full corpus: ε round-trips,
    // worker byte-stability, clean reports, both containers, and
    // bit-identical decodes across the xsz/ftxsz protection pair. (The
    // ratio claim — bits beat bytes on smooth fields — lives in the xsz
    // unit tests and the hotpath --check gate, at representative block
    // sizes; this corpus's block size 4 makes per-block header costs
    // dominate.)
    let bound = 1e-3;
    for case in corpus() {
        for parity in [false, true] {
            let mut cfg = CompressionConfig::new(ErrorBound::Abs(bound))
                .with_block_size(4)
                .with_xsz_bitpack(true);
            if parity {
                cfg = cfg.with_archive_parity(ParityParams::xor(64, 8));
            }
            let mut pair_bits: Vec<Vec<u32>> = Vec::new();
            for e in [Engine::UltraFast, Engine::UltraFastFT] {
                let codec = e.codec();
                let base = codec.compress(&case.data, case.dims, &cfg).unwrap_or_else(|err| {
                    panic!("{} bitpack: compress failed: {err}", case.repro(e, 1, parity))
                });
                for workers in [1usize, 2, 4] {
                    let b = codec
                        .compress(&case.data, case.dims, &cfg.clone().with_workers(workers))
                        .unwrap_or_else(|err| {
                            panic!(
                                "{} bitpack: compress failed: {err}",
                                case.repro(e, workers, parity)
                            )
                        });
                    assert_eq!(
                        b,
                        base,
                        "{} bitpack: archive bytes differ from the 1-worker reference",
                        case.repro(e, workers, parity)
                    );
                    let dec = codec
                        .decompress(&base, Parallelism::from_workers(workers))
                        .unwrap_or_else(|err| {
                            panic!(
                                "{} bitpack: decompress failed: {err}",
                                case.repro(e, workers, parity)
                            )
                        });
                    let max = analysis::max_abs_err(&case.data, &dec.data);
                    assert!(
                        max <= bound,
                        "{} bitpack: bound violated ({max} > {bound})",
                        case.repro(e, workers, parity)
                    );
                    if workers == 1 {
                        pair_bits.push(dec.data.iter().map(|v| v.to_bits()).collect());
                    }
                }
                let report = report_of(e, &base).unwrap_or_else(|err| {
                    panic!(
                        "{} bitpack: reporting decode failed: {err}",
                        case.repro(e, 1, parity)
                    )
                });
                assert!(
                    report.is_clean(),
                    "{} bitpack: clean archive reported events: {report:?}",
                    case.repro(e, 1, parity)
                );
            }
            assert_eq!(
                pair_bits[0],
                pair_bits[1],
                "xsz vs ftxsz bitpack decode bits differ: {}",
                case.repro(Engine::UltraFast, 1, parity)
            );
        }
    }
}

#[test]
fn differential_decodes_agree_where_numerics_are_shared() {
    // rsz/ftrsz and xsz/ftxsz are protection pairs over identical
    // numerics: the archives differ (ft sections) but the decoded bits
    // must not. (Classic has different numerics by design — cross-block
    // prediction — so it only shares the ε contract, not the bits.)
    for case in corpus() {
        let cfg = CompressionConfig::new(ErrorBound::Abs(1e-3)).with_block_size(4);
        for (plain, protected) in [
            (Engine::RandomAccess, Engine::FaultTolerant),
            (Engine::UltraFast, Engine::UltraFastFT),
        ] {
            let a = plain.codec().compress(&case.data, case.dims, &cfg).unwrap();
            let b = protected.codec().compress(&case.data, case.dims, &cfg).unwrap();
            let da = plain.codec().decompress(&a, Parallelism::Sequential).unwrap();
            let db = protected.codec().decompress(&b, Parallelism::Sequential).unwrap();
            assert_eq!(
                da.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                db.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{} vs {}: {}",
                plain.name(),
                protected.name(),
                case.repro(plain, 1, false)
            );
        }
    }
}

#[test]
fn differential_region_decode_matches_full_slice_on_region_engines() {
    // the region contract, cross-engine: every engine that claims
    // supports_region() must produce the full-decode slice bitwise
    let case = Case {
        kind: "smooth",
        seed: 321,
        dims: Dims::d3(9, 11, 13),
        data: smooth(321, Dims::d3(9, 11, 13)),
    };
    let cfg = CompressionConfig::new(ErrorBound::Abs(1e-3)).with_block_size(4);
    let region = ftsz::compressor::block::Region { origin: (2, 3, 4), shape: (5, 6, 7) };
    let (_, ry, rx) = case.dims.as_3d();
    for e in Engine::ALL {
        let codec = e.codec();
        if !codec.supports_region() {
            continue;
        }
        let bytes = codec.compress(&case.data, case.dims, &cfg).unwrap();
        let full = codec.decompress(&bytes, Parallelism::Sequential).unwrap();
        for workers in [1usize, 4] {
            let got = codec
                .decompress_region(&bytes, region, Parallelism::from_workers(workers))
                .unwrap_or_else(|err| {
                    panic!("{}: region decode failed: {err}", case.repro(e, workers, false))
                });
            let mut idx = 0;
            for z in 0..region.shape.0 {
                for y in 0..region.shape.1 {
                    for x in 0..region.shape.2 {
                        let g = ((region.origin.0 + z) * ry + region.origin.1 + y) * rx
                            + region.origin.2
                            + x;
                        assert_eq!(
                            got[idx].to_bits(),
                            full.data[g].to_bits(),
                            "{}: region mismatch at ({z},{y},{x})",
                            case.repro(e, workers, false)
                        );
                        idx += 1;
                    }
                }
            }
        }
    }
}

#[test]
fn corpus_is_well_formed() {
    // the harness's own precondition: finite data, matching lengths
    for case in corpus() {
        assert_eq!(case.data.len(), case.dims.len(), "{} seed {}", case.kind, case.seed);
        assert!(
            case.data.iter().all(|v| v.is_finite()),
            "{} seed {}: non-finite corpus value",
            case.kind,
            case.seed
        );
        // Field construction validates dims/data agreement too
        let _ = Field::new(case.kind, case.dims, case.data).unwrap();
    }
}
