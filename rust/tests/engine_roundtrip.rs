//! Integration: engine round-trips across datasets, bounds, predictors,
//! ranks and block sizes — the error-bound contract end to end.

use ftsz::analysis;
use ftsz::compressor::block::Region;
use ftsz::compressor::{classic, engine, CompressionConfig, ErrorBound, PredictorPolicy};
use ftsz::data::synthetic::{self, Profile};
use ftsz::data::Dims;
use ftsz::ft;
use ftsz::inject::Engine;
use ftsz::util::rng::Pcg32;

fn compress_any(e: Engine, data: &[f32], dims: Dims, cfg: &CompressionConfig) -> Vec<u8> {
    match e {
        Engine::Classic => classic::compress(data, dims, cfg).unwrap(),
        Engine::RandomAccess => engine::compress(data, dims, cfg).unwrap(),
        Engine::FaultTolerant => ft::compress(data, dims, cfg).unwrap(),
        Engine::UltraFast => ftsz::compressor::xsz::compress(data, dims, cfg).unwrap(),
        Engine::UltraFastFT => ftsz::compressor::xsz::compress_ft(data, dims, cfg).unwrap(),
    }
}

fn decompress_any(e: Engine, bytes: &[u8]) -> Vec<f32> {
    match e {
        Engine::Classic => classic::decompress(bytes).unwrap().data,
        Engine::RandomAccess | Engine::UltraFast => engine::decompress(bytes).unwrap().data,
        Engine::FaultTolerant | Engine::UltraFastFT => ft::decompress(bytes).unwrap().data,
    }
}

#[test]
fn all_profiles_all_engines_all_bounds() {
    for profile in Profile::all() {
        let f = synthetic::dataset(profile, 32, 5).remove(0);
        for e in Engine::ALL {
            for bound in [1e-2, 1e-4] {
                let cfg = CompressionConfig::new(ErrorBound::Rel(bound));
                let abs = cfg.error_bound.absolute(&f.data);
                let bytes = compress_any(e, &f.data, f.dims, &cfg);
                let dec = decompress_any(e, &bytes);
                let max = analysis::max_abs_err(&f.data, &dec);
                assert!(
                    max <= abs,
                    "{} {} bound {bound}: {max} > {abs}",
                    profile.name(),
                    e.name()
                );
            }
        }
    }
}

#[test]
fn forced_predictors_both_respect_bound() {
    let f = synthetic::dataset(Profile::Hurricane, 32, 9).remove(0);
    for policy in [PredictorPolicy::LorenzoOnly, PredictorPolicy::RegressionOnly] {
        let cfg = CompressionConfig::new(ErrorBound::Abs(1e-3)).with_predictor(policy);
        let bytes = engine::compress(&f.data, f.dims, &cfg).unwrap();
        let dec = engine::decompress(&bytes).unwrap();
        assert!(analysis::max_abs_err(&f.data, &dec.data) <= 1e-3, "{policy:?}");
    }
}

#[test]
fn auto_never_loses_to_both_forced_policies() {
    // auto selection should be at least as small as the worse forced policy
    let f = synthetic::dataset(Profile::Nyx, 32, 11).remove(0);
    let base = CompressionConfig::new(ErrorBound::Rel(1e-3));
    let auto = engine::compress(&f.data, f.dims, &base).unwrap().len();
    let lor = engine::compress(
        &f.data,
        f.dims,
        &base.clone().with_predictor(PredictorPolicy::LorenzoOnly),
    )
    .unwrap()
    .len();
    let reg = engine::compress(
        &f.data,
        f.dims,
        &base.clone().with_predictor(PredictorPolicy::RegressionOnly),
    )
    .unwrap()
    .len();
    assert!(
        auto <= lor.max(reg),
        "auto {auto} worse than both lorenzo {lor} and regression {reg}"
    );
}

#[test]
fn quant_radius_variants_roundtrip() {
    let f = synthetic::dataset(Profile::ScaleLetkf, 32, 3).remove(0);
    for radius in [256u32, 4096, 32768] {
        let cfg = CompressionConfig::new(ErrorBound::Rel(1e-3)).with_quant_radius(radius);
        let bytes = engine::compress(&f.data, f.dims, &cfg).unwrap();
        let dec = engine::decompress(&bytes).unwrap();
        let abs = cfg.error_bound.absolute(&f.data);
        assert!(analysis::max_abs_err(&f.data, &dec.data) <= abs, "radius {radius}");
    }
}

#[test]
fn tiny_and_awkward_shapes() {
    let mut rng = Pcg32::new(1);
    for dims in [
        Dims::d1(1),
        Dims::d1(7),
        Dims::d2(1, 13),
        Dims::d2(3, 1),
        Dims::d3(1, 1, 1),
        Dims::d3(2, 3, 5),
        Dims::d3(11, 1, 17),
    ] {
        let data: Vec<f32> = (0..dims.len()).map(|_| rng.normal() as f32).collect();
        let cfg = CompressionConfig::new(ErrorBound::Abs(1e-2)).with_block_size(4);
        let bytes = ft::compress(&data, dims, &cfg).unwrap();
        let dec = ft::decompress(&bytes).unwrap();
        assert!(analysis::max_abs_err(&data, &dec.data) <= 1e-2, "{dims:?}");
    }
}

#[test]
fn constant_and_extreme_fields() {
    let dims = Dims::d3(8, 8, 8);
    for fill in [0.0f32, -0.0, 1e30, -1e30, 1e-30, 3.14159] {
        let data = vec![fill; dims.len()];
        let cfg = CompressionConfig::new(ErrorBound::Abs(1e-3));
        let bytes = ft::compress(&data, dims, &cfg).unwrap();
        let dec = ft::decompress(&bytes).unwrap();
        assert!(analysis::max_abs_err(&data, &dec.data) <= 1e-3, "fill {fill}");
        // constants compress extremely well
        if fill.abs() < 1e20 {
            assert!(bytes.len() < dims.len(), "constant field barely compressed");
        }
    }
}

#[test]
fn random_regions_match_full_decompression() {
    let f = synthetic::dataset(Profile::Hurricane, 32, 13).remove(0);
    let cfg = CompressionConfig::new(ErrorBound::Rel(1e-3)).with_block_size(6);
    let bytes = engine::compress(&f.data, f.dims, &cfg).unwrap();
    let full = engine::decompress(&bytes).unwrap();
    let (d, r, c) = f.dims.as_3d();
    let mut rng = Pcg32::new(77);
    for _ in 0..25 {
        let oz = rng.index(d);
        let oy = rng.index(r);
        let ox = rng.index(c);
        let region = Region {
            origin: (oz, oy, ox),
            shape: (
                1 + rng.index(d - oz),
                1 + rng.index(r - oy),
                1 + rng.index(c - ox),
            ),
        };
        let got = engine::decompress_region(&bytes, region).unwrap();
        let mut idx = 0;
        for z in 0..region.shape.0 {
            for y in 0..region.shape.1 {
                for x in 0..region.shape.2 {
                    let g = ((region.origin.0 + z) * r + region.origin.1 + y) * c
                        + region.origin.2
                        + x;
                    assert_eq!(got[idx].to_bits(), full.data[g].to_bits());
                    idx += 1;
                }
            }
        }
    }
}

#[test]
fn deterministic_archives() {
    // same input + config => byte-identical archives (required for
    // reproducible experiments and checksum stability)
    let f = synthetic::dataset(Profile::Pluto, 24, 21).remove(0);
    let cfg = CompressionConfig::new(ErrorBound::Rel(1e-4));
    let a = ft::compress(&f.data, f.dims, &cfg).unwrap();
    let b = ft::compress(&f.data, f.dims, &cfg).unwrap();
    assert_eq!(a, b);
}

#[test]
fn f64_checksum_path_is_exposed() {
    // the paper's double-precision scheme: two u32 words per double
    use ftsz::ft::checksum::{checksum_f64, diagnose, Diagnosis};
    let data: Vec<f64> = (0..500).map(|i| (i as f64).sqrt()).collect();
    let c0 = checksum_f64(&data);
    let mut bad = data.clone();
    bad[123] = f64::from_bits(bad[123].to_bits() ^ (1 << 57));
    match diagnose(c0, checksum_f64(&bad), 2 * bad.len()) {
        Diagnosis::SingleError { index, .. } => assert_eq!(index / 2, 123),
        other => panic!("{other:?}"),
    }
}
