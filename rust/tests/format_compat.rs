//! Archive-format robustness: parsing hostile/corrupt/truncated inputs
//! must never panic or over-allocate, version/flag gating works, and the
//! v1 ↔ v2 cross-version contract holds (v1 bytes unchanged, identical
//! decoded content, v2 self-healing).

use ftsz::compressor::{classic, engine, format, CompressionConfig, ErrorBound};
use ftsz::data::{synthetic, Dims};
use ftsz::ft;
use ftsz::ft::parity::ParityParams;
use ftsz::inject::{classify_archive, ArchiveOutcome};
use ftsz::util::rng::Pcg32;

fn sample_field() -> ftsz::data::Field {
    synthetic::hurricane_field("t", Dims::d3(8, 12, 12), 3)
}

fn sample_cfg() -> CompressionConfig {
    CompressionConfig::new(ErrorBound::Abs(1e-3)).with_block_size(6)
}

fn sample_archive() -> Vec<u8> {
    let f = sample_field();
    ft::compress(&f.data, f.dims, &sample_cfg()).unwrap()
}

fn sample_archive_v2() -> Vec<u8> {
    let f = sample_field();
    let cfg = sample_cfg()
        .with_archive_parity(ParityParams::xor(128, 16));
    ft::compress(&f.data, f.dims, &cfg).unwrap()
}

#[test]
fn empty_and_garbage_inputs() {
    assert!(format::parse(&[]).is_err());
    assert!(format::parse(b"FTSZ").is_err());
    assert!(format::parse(b"NOPE00000000000000000000").is_err());
    let mut rng = Pcg32::new(5);
    for len in [1usize, 16, 100, 1000] {
        let junk: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
        assert!(format::parse(&junk).is_err(), "len {len} parsed");
    }
}

#[test]
fn every_truncation_point_errors_cleanly() {
    let bytes = sample_archive();
    for cut in 0..bytes.len() {
        assert!(format::parse(&bytes[..cut]).is_err(), "prefix {cut} parsed");
    }
}

#[test]
fn wrong_version_rejected() {
    let mut bytes = sample_archive();
    bytes[4] = 99; // version field
    assert!(matches!(format::parse(&bytes), Err(ftsz::Error::Format(_))));
}

#[test]
fn section_length_bombs_rejected() {
    // blow up a section length field; the parser must cap, not allocate
    let bytes = sample_archive();
    let parsed = format::parse(&bytes).unwrap();
    assert!(parsed.header.is_fault_tolerant());
    // find the first section length (fixed header is 4+4+4+1+24+4+4+8+8=61)
    let mut bomb = bytes.clone();
    for b in bomb[61..69].iter_mut() {
        *b = 0xFF;
    }
    assert!(format::parse(&bomb).is_err());
}

#[test]
fn fuzz_bitflips_parse_or_fail_without_panic() {
    let bytes = sample_archive();
    let mut rng = Pcg32::new(11);
    for _ in 0..400 {
        let mut bad = bytes.clone();
        let pos = rng.index(bad.len());
        bad[pos] ^= 1 << rng.index(8);
        // outcome may be Ok (flip in slack space) or Err; never panic
        match format::parse(&bad) {
            Ok(a) => {
                // decoding may still fail cleanly
                let _ = ft::decompress(&bad);
                let _ = a;
            }
            Err(_) => {}
        }
    }
}

#[test]
fn engine_type_gating() {
    let f = synthetic::hurricane_field("t", Dims::d3(6, 8, 8), 9);
    let cfg = CompressionConfig::new(ErrorBound::Abs(1e-2)).with_block_size(4);
    let rsz = engine::compress(&f.data, f.dims, &cfg).unwrap();
    let sz = classic::compress(&f.data, f.dims, &cfg).unwrap();
    // cross-engine decode attempts must error, not misdecode
    assert!(classic::decompress(&rsz).is_err());
    assert!(engine::decompress(&sz).is_err());
    // verification requires an ft archive
    assert!(ft::decompress(&rsz).is_err());
}

#[test]
fn header_fields_roundtrip_exactly() {
    let f = synthetic::pluto_image("p", 24, 40, 1);
    let cfg = CompressionConfig::new(ErrorBound::Abs(2.5e-4)).with_block_size(7);
    let bytes = ft::compress(&f.data, f.dims, &cfg).unwrap();
    let a = format::parse(&bytes).unwrap();
    assert_eq!(a.header.dims, Dims::d2(24, 40));
    assert_eq!(a.header.block_size, 7);
    assert_eq!(a.header.error_bound, 2.5e-4);
    assert!(a.header.is_random_access());
    assert!(a.header.is_fault_tolerant());
    assert!(!a.header.is_classic());
    assert_eq!(a.metas.len() as u64, a.header.n_blocks);
    assert_eq!(a.sum_dc.as_ref().unwrap().len(), a.metas.len());
}

#[test]
fn current_writer_defaults_to_v1_bytes() {
    // back-compat contract: without the parity knob the writer emits
    // version-1 archives, and they parse with no v2 machinery involved
    let bytes = sample_archive();
    assert_eq!(&bytes[..4], b"FTSZ");
    assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()), format::VERSION);
    let a = format::parse(&bytes).unwrap();
    assert_eq!(a.version, format::VERSION);
    assert!(a.parity.is_none());
    assert!(!a.header.has_archive_parity());
}

#[test]
fn v1_and_v2_decode_bitwise_identically() {
    let f = sample_field();
    let v1 = sample_archive();
    let v2 = sample_archive_v2();
    assert_eq!(u32::from_le_bytes(v2[4..8].try_into().unwrap()), format::VERSION_V2);
    let a = format::parse(&v2).unwrap();
    assert!(a.header.has_archive_parity());
    assert_eq!(a.parity, Some(ParityParams::xor(128, 16)));
    let d1 = ft::decompress(&v1).unwrap();
    let d2 = ft::decompress(&v2).unwrap();
    assert_eq!(
        d1.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        d2.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
    );
    let bound = 1e-3;
    assert!(ftsz::analysis::max_abs_err(&f.data, &d2.data) <= bound);
}

#[test]
fn v2_truncation_points_error_cleanly() {
    let bytes = sample_archive_v2();
    // step 7 keeps the sweep fast on the (larger) v2 archive while still
    // covering every region; the v1 sweep above stays exhaustive
    for cut in (0..bytes.len()).step_by(7) {
        assert!(format::parse(&bytes[..cut]).is_err(), "v2 prefix {cut} parsed");
        assert!(ft::decompress(&bytes[..cut]).is_err(), "v2 prefix {cut} decoded");
    }
}

#[test]
fn v2_fuzz_bitflips_heal_or_fail_cleanly_never_lie() {
    let f = sample_field();
    let bytes = sample_archive_v2();
    let mut rng = Pcg32::new(29);
    let mut corrected = 0usize;
    for _ in 0..300 {
        let mut bad = bytes.clone();
        let pos = rng.index(bad.len());
        bad[pos] ^= 1 << rng.index(8);
        match classify_archive(&f.data, 1e-3, ft::decompress(&bad)) {
            ArchiveOutcome::Corrected => corrected += 1,
            ArchiveOutcome::CleanError => {}
            ArchiveOutcome::SilentSdc => panic!("silent SDC from flip at {pos}"),
        }
    }
    assert!(corrected >= 285, "only {corrected}/300 single flips healed");
}

#[test]
fn v2_parallel_compress_is_byte_identical() {
    let f = sample_field();
    let cfg = sample_cfg().with_archive_parity(ParityParams::default());
    let seq = ft::compress(&f.data, f.dims, &cfg).unwrap();
    for w in [2usize, 4] {
        let par = ft::compress(&f.data, f.dims, &cfg.clone().with_workers(w)).unwrap();
        assert_eq!(par, seq, "v2 archive differs at {w} workers");
    }
}

#[test]
fn v2_region_decode_and_classic_roundtrip() {
    // the parity layer is engine-agnostic: rsz region decode and the
    // classic engine both ride on the same recovery pass
    let f = sample_field();
    let cfg = sample_cfg().with_archive_parity(ParityParams::xor(64, 8));
    let rsz = engine::compress(&f.data, f.dims, &cfg).unwrap();
    let region = ftsz::compressor::block::Region { origin: (1, 2, 3), shape: (4, 5, 6) };
    let clean_region = engine::decompress_region(&rsz, region).unwrap();
    let mut damaged = rsz.clone();
    damaged[rsz.len() / 2] ^= 0x08;
    let healed_region = engine::decompress_region(&damaged, region).unwrap();
    assert_eq!(clean_region, healed_region);
    let sz = classic::compress(&f.data, f.dims, &cfg).unwrap();
    assert_eq!(u32::from_le_bytes(sz[4..8].try_into().unwrap()), format::VERSION_V2);
    let mut damaged = sz.clone();
    damaged[sz.len() / 2] ^= 0x08;
    let dec = classic::decompress(&damaged).unwrap();
    assert!(ftsz::analysis::max_abs_err(&f.data, &dec.data) <= 1e-3);
}

#[test]
fn unpred_counts_validated() {
    // corrupting the unpredictable counts must be caught at parse or decode
    let bytes = sample_archive();
    let mut rng = Pcg32::new(13);
    let mut seen_reject = false;
    for _ in 0..200 {
        let mut bad = bytes.clone();
        let pos = rng.index(bad.len());
        bad[pos] = bad[pos].wrapping_add(1 + rng.next_u32() as u8 % 254);
        if format::parse(&bad).is_err() || ft::decompress(&bad).is_err() {
            seen_reject = true;
        }
    }
    assert!(seen_reject, "no corruption was ever rejected?");
}

// ---------------------------------------------------------------------
// v1 → v2 transcode: wrap existing archives in protection without
// recompressing a single section byte
// ---------------------------------------------------------------------

fn compress_any(e: ftsz::inject::Engine, cfg: &CompressionConfig) -> Vec<u8> {
    let f = sample_field();
    match e {
        ftsz::inject::Engine::Classic => classic::compress(&f.data, f.dims, cfg).unwrap(),
        ftsz::inject::Engine::RandomAccess => engine::compress(&f.data, f.dims, cfg).unwrap(),
        ftsz::inject::Engine::FaultTolerant => ft::compress(&f.data, f.dims, cfg).unwrap(),
        ftsz::inject::Engine::UltraFast => {
            ftsz::compressor::xsz::compress(&f.data, f.dims, cfg).unwrap()
        }
        ftsz::inject::Engine::UltraFastFT => {
            ftsz::compressor::xsz::compress_ft(&f.data, f.dims, cfg).unwrap()
        }
    }
}

fn decompress_any_bits(e: ftsz::inject::Engine, bytes: &[u8]) -> Vec<u32> {
    let data = match e {
        ftsz::inject::Engine::Classic => classic::decompress(bytes).unwrap().data,
        ftsz::inject::Engine::RandomAccess | ftsz::inject::Engine::UltraFast => {
            engine::decompress(bytes).unwrap().data
        }
        ftsz::inject::Engine::FaultTolerant | ftsz::inject::Engine::UltraFastFT => {
            ft::decompress(bytes).unwrap().data
        }
    };
    data.iter().map(|v| v.to_bits()).collect()
}

/// Concatenated bodies of the four v1 sections (meta, unpred, payload,
/// ft), extracted straight from the v1 framing: 61-byte fixed header,
/// then four `len u64 | body` records.
fn v1_section_bodies(v1: &[u8]) -> Vec<u8> {
    let mut at = 61usize;
    let mut out = Vec::new();
    for _ in 0..4 {
        let len =
            u64::from_le_bytes(v1[at..at + 8].try_into().unwrap()) as usize;
        at += 8;
        out.extend_from_slice(&v1[at..at + len]);
        at += len;
    }
    assert_eq!(at, v1.len(), "v1 framing: trailing bytes");
    out
}

#[test]
fn transcode_matrix_all_engines_bit_identical_without_recompression() {
    for e in ftsz::inject::Engine::ALL {
        let v1 = compress_any(e, &sample_cfg());
        assert_eq!(u32::from_le_bytes(v1[4..8].try_into().unwrap()), format::VERSION);
        let want = decompress_any_bits(e, &v1);
        let bodies = v1_section_bodies(&v1);
        for params in [ParityParams::xor(128, 16), ParityParams::rs(128, 16, 3)] {
            let v2 = format::transcode_v1_to_v2(&v1, params).unwrap();
            assert_eq!(
                u32::from_le_bytes(v2[4..8].try_into().unwrap()),
                format::VERSION_V2,
                "{} {params:?}",
                e.name()
            );
            let parsed = format::parse(&v2).unwrap();
            assert!(parsed.header.has_archive_parity());
            assert_eq!(parsed.parity, Some(params), "{}", e.name());
            // bit-identical decode through the engine's own path
            assert_eq!(decompress_any_bits(e, &v2), want, "{} {params:?}", e.name());
            // no recompression: the v1 section bodies appear verbatim as
            // one contiguous run inside the v2 archive
            assert!(
                v2.windows(bodies.len()).any(|w| w == &bodies[..]),
                "{} {params:?}: transcoded archive does not reuse the v1 section bytes",
                e.name()
            );
            // the wrapped archive actually protects: a mid-archive flip
            // heals back to the same bits
            let mut damaged = v2.clone();
            damaged[v2.len() / 2] ^= 0x20;
            assert_eq!(decompress_any_bits(e, &damaged), want, "{} {params:?}", e.name());
        }
    }
}

#[test]
fn transcoded_rs_archive_heals_multi_stripe_damage() {
    let v1 = sample_archive();
    let want = ft::decompress(&v1).unwrap().data;
    let v2 = format::transcode_v1_to_v2(&v1, ParityParams::rs(64, 8, 3)).unwrap();
    let mut rng = Pcg32::new(77);
    for trial in 0..20 {
        let mut bad = v2.clone();
        ftsz::inject::mode_c::strike(
            &mut bad,
            &mut rng,
            ftsz::inject::mode_c::ArchiveFault::GroupBurst { stripes: 3 },
        );
        assert_ne!(bad, v2, "trial {trial}: strike was a no-op");
        let dec = ft::decompress(&bad).unwrap();
        assert_eq!(
            dec.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "trial {trial}: 3-stripe damage not healed exactly"
        );
    }
}

#[test]
fn transcode_rejects_v2_garbage_and_trailing_bytes() {
    let params = ParityParams::default();
    // already-protected input: refuse rather than double-wrap
    assert!(format::transcode_v1_to_v2(&sample_archive_v2(), params).is_err());
    // garbage and truncation
    assert!(format::transcode_v1_to_v2(&[], params).is_err());
    assert!(format::transcode_v1_to_v2(b"NOPE0000", params).is_err());
    let v1 = sample_archive();
    assert!(format::transcode_v1_to_v2(&v1[..v1.len() - 3], params).is_err());
    // trailing junk after the sections must not be silently dropped
    let mut padded = v1.clone();
    padded.extend_from_slice(b"\0\0\0");
    assert!(format::transcode_v1_to_v2(&padded, params).is_err());
    // the transcoded output itself round-trips through parse + scrub clean
    let v2 = format::transcode_v1_to_v2(&v1, params).unwrap();
    let (outcome, _) = ftsz::ft::parity::scrub(&v2).unwrap();
    assert!(matches!(outcome, ftsz::ft::parity::ScrubOutcome::Clean));
}
