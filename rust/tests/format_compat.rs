//! Archive-format robustness: parsing hostile/corrupt/truncated inputs
//! must never panic or over-allocate, and version/flag gating works.

use ftsz::compressor::{classic, engine, format, CompressionConfig, ErrorBound};
use ftsz::data::{synthetic, Dims};
use ftsz::ft;
use ftsz::util::rng::Pcg32;

fn sample_archive() -> Vec<u8> {
    let f = synthetic::hurricane_field("t", Dims::d3(8, 12, 12), 3);
    let cfg = CompressionConfig::new(ErrorBound::Abs(1e-3)).with_block_size(6);
    ft::compress(&f.data, f.dims, &cfg).unwrap()
}

#[test]
fn empty_and_garbage_inputs() {
    assert!(format::parse(&[]).is_err());
    assert!(format::parse(b"FTSZ").is_err());
    assert!(format::parse(b"NOPE00000000000000000000").is_err());
    let mut rng = Pcg32::new(5);
    for len in [1usize, 16, 100, 1000] {
        let junk: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
        assert!(format::parse(&junk).is_err(), "len {len} parsed");
    }
}

#[test]
fn every_truncation_point_errors_cleanly() {
    let bytes = sample_archive();
    for cut in 0..bytes.len() {
        assert!(format::parse(&bytes[..cut]).is_err(), "prefix {cut} parsed");
    }
}

#[test]
fn wrong_version_rejected() {
    let mut bytes = sample_archive();
    bytes[4] = 99; // version field
    assert!(matches!(format::parse(&bytes), Err(ftsz::Error::Format(_))));
}

#[test]
fn section_length_bombs_rejected() {
    // blow up a section length field; the parser must cap, not allocate
    let bytes = sample_archive();
    let parsed = format::parse(&bytes).unwrap();
    assert!(parsed.header.is_fault_tolerant());
    // find the first section length (fixed header is 4+4+4+1+24+4+4+8+8=61)
    let mut bomb = bytes.clone();
    for b in bomb[61..69].iter_mut() {
        *b = 0xFF;
    }
    assert!(format::parse(&bomb).is_err());
}

#[test]
fn fuzz_bitflips_parse_or_fail_without_panic() {
    let bytes = sample_archive();
    let mut rng = Pcg32::new(11);
    for _ in 0..400 {
        let mut bad = bytes.clone();
        let pos = rng.index(bad.len());
        bad[pos] ^= 1 << rng.index(8);
        // outcome may be Ok (flip in slack space) or Err; never panic
        match format::parse(&bad) {
            Ok(a) => {
                // decoding may still fail cleanly
                let _ = ft::decompress(&bad);
                let _ = a;
            }
            Err(_) => {}
        }
    }
}

#[test]
fn engine_type_gating() {
    let f = synthetic::hurricane_field("t", Dims::d3(6, 8, 8), 9);
    let cfg = CompressionConfig::new(ErrorBound::Abs(1e-2)).with_block_size(4);
    let rsz = engine::compress(&f.data, f.dims, &cfg).unwrap();
    let sz = classic::compress(&f.data, f.dims, &cfg).unwrap();
    // cross-engine decode attempts must error, not misdecode
    assert!(classic::decompress(&rsz).is_err());
    assert!(engine::decompress(&sz).is_err());
    // verification requires an ft archive
    assert!(ft::decompress(&rsz).is_err());
}

#[test]
fn header_fields_roundtrip_exactly() {
    let f = synthetic::pluto_image("p", 24, 40, 1);
    let cfg = CompressionConfig::new(ErrorBound::Abs(2.5e-4)).with_block_size(7);
    let bytes = ft::compress(&f.data, f.dims, &cfg).unwrap();
    let a = format::parse(&bytes).unwrap();
    assert_eq!(a.header.dims, Dims::d2(24, 40));
    assert_eq!(a.header.block_size, 7);
    assert_eq!(a.header.error_bound, 2.5e-4);
    assert!(a.header.is_random_access());
    assert!(a.header.is_fault_tolerant());
    assert!(!a.header.is_classic());
    assert_eq!(a.metas.len() as u64, a.header.n_blocks);
    assert_eq!(a.sum_dc.as_ref().unwrap().len(), a.metas.len());
}

#[test]
fn unpred_counts_validated() {
    // corrupting the unpredictable counts must be caught at parse or decode
    let bytes = sample_archive();
    let mut rng = Pcg32::new(13);
    let mut seen_reject = false;
    for _ in 0..200 {
        let mut bad = bytes.clone();
        let pos = rng.index(bad.len());
        bad[pos] = bad[pos].wrapping_add(1 + rng.next_u32() as u8 % 254);
        if format::parse(&bad).is_err() || ft::decompress(&bad).is_err() {
            seen_reject = true;
        }
    }
    assert!(seen_reject, "no corruption was ever rejected?");
}
