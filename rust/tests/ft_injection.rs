//! Integration: the paper's core claim — ftrsz survives SDCs that break
//! unprotected SZ (Table 3, Fig. 6, §6.4.4).

use ftsz::compressor::engine::{DecompressHooks, NoHooks};
use ftsz::compressor::{CompressionConfig, ErrorBound};
use ftsz::data::{synthetic, Dims};
use ftsz::ft;
use ftsz::ft::report::SdcKind;
use ftsz::inject::mode_a::{BinBitFlip, DecompFault, EstimationFault, InputBitFlip, PredFault};
use ftsz::inject::mode_b::ArenaFlip;
use ftsz::inject::{run_and_classify, Engine, Outcome};

fn field() -> ftsz::data::Field {
    synthetic::hurricane_field("t", Dims::d3(10, 20, 20), 77)
}

fn cfg() -> CompressionConfig {
    CompressionConfig::new(ErrorBound::Abs(1e-3)).with_block_size(8)
}

fn n_blocks(dims: Dims, b: usize) -> usize {
    let (d, r, c) = dims.as_3d();
    d.div_ceil(b) * r.div_ceil(b) * c.div_ceil(b)
}

#[test]
fn input_bitflips_always_corrected_by_ftrsz() {
    let f = field();
    for seed in 0..30 {
        let mut inj = InputBitFlip::new(seed, 1);
        let o = run_and_classify(Engine::FaultTolerant, &f.data, f.dims, &cfg(), &mut inj);
        assert_eq!(o, Outcome::Correct, "seed {seed}: ftrsz must correct input flips");
    }
}

#[test]
fn input_bitflips_often_break_unprotected_sz() {
    let f = field();
    let mut incorrect = 0;
    let n = 40;
    for seed in 0..n {
        let mut inj = InputBitFlip::new(seed, 1);
        let o = run_and_classify(Engine::RandomAccess, &f.data, f.dims, &cfg(), &mut inj);
        if o != Outcome::Correct {
            incorrect += 1;
        }
    }
    // high exponent/sign bits corrupt the value beyond the bound; the paper
    // sees ~40-50% of unprotected runs fail — require a nonzero failure
    // rate here (the exact share depends on bit position distribution)
    assert!(incorrect > n / 5, "only {incorrect}/{n} unprotected runs failed");
}

#[test]
fn bin_bitflips_corrected_by_ftrsz() {
    let f = field();
    let nb = n_blocks(f.dims, 8);
    for seed in 0..30 {
        let mut inj = BinBitFlip::new(seed, nb);
        let o = run_and_classify(Engine::FaultTolerant, &f.data, f.dims, &cfg(), &mut inj);
        assert_eq!(o, Outcome::Correct, "seed {seed}");
    }
}

#[test]
fn bin_bitflips_crash_or_break_unprotected_engines() {
    let f = field();
    let nb = n_blocks(f.dims, 8);
    let mut bad = 0;
    let mut crashes = 0;
    let n = 40;
    for seed in 0..n {
        let mut inj = BinBitFlip::new(seed, nb);
        match run_and_classify(Engine::RandomAccess, &f.data, f.dims, &cfg(), &mut inj) {
            Outcome::Correct => {}
            Outcome::Crash => {
                crashes += 1;
                bad += 1;
            }
            _ => bad += 1,
        }
    }
    assert!(bad > n / 4, "bin flips should usually break rsz: {bad}/{n}");
    assert!(crashes > 0, "high-bit flips should crash (out-of-table codes)");
}

#[test]
fn estimation_faults_never_affect_correctness() {
    // §4.1.1: computation errors in regression/sampling only cost ratio
    let f = field();
    let nb = n_blocks(f.dims, 8);
    for engine in [Engine::RandomAccess, Engine::FaultTolerant] {
        for seed in 0..15 {
            let mut inj = EstimationFault::new(seed, nb, 3);
            let o = run_and_classify(engine, &f.data, f.dims, &cfg(), &mut inj);
            assert_eq!(o, Outcome::Correct, "engine {} seed {seed}", engine.name());
        }
    }
}

#[test]
fn pred_faults_caught_by_duplication() {
    let f = field();
    let nb = n_blocks(f.dims, 8);
    for seed in 0..30 {
        let mut inj = PredFault::new(seed, nb, 512);
        let out = ft::compress_with_hooks(&f.data, f.dims, &cfg(), &mut inj).unwrap();
        if inj.applied {
            assert!(
                out.stats.dup_pred_catches >= 1,
                "seed {seed}: duplication must catch the pred fault"
            );
        }
        let dec = ft::decompress(&out.archive).unwrap();
        let max = ftsz::analysis::max_abs_err(&f.data, &dec.data);
        assert!(max <= 1e-3, "seed {seed}: bound violated {max}");
    }
}

#[test]
fn pred_faults_can_silently_break_unprotected_rsz() {
    let f = field();
    let nb = n_blocks(f.dims, 8);
    let mut incorrect = 0;
    for seed in 0..60 {
        let mut inj = PredFault::new(seed, nb, 512);
        let o = run_and_classify(Engine::RandomAccess, &f.data, f.dims, &cfg(), &mut inj);
        if o == Outcome::Incorrect {
            incorrect += 1;
        }
    }
    // Case 1 Situation 2 (§4.1.2): some flips stay under the quantization
    // range and silently poison the decompression
    assert!(incorrect > 0, "expected at least one silent corruption");
}

#[test]
fn decompression_faults_detected_and_corrected() {
    // §6.4.4: inject one computation error per decompression; 100% detected
    // by sum_dc and corrected by block re-execution
    let f = field();
    let nb = n_blocks(f.dims, 8);
    let bytes = ft::compress(&f.data, f.dims, &cfg()).unwrap();
    let mut corrected_runs = 0;
    for seed in 0..30 {
        let mut inj = DecompFault::new(seed, nb, 512);
        let (dec, report) = ft::decompress_verbose(&bytes, &mut inj).unwrap();
        let max = ftsz::analysis::max_abs_err(&f.data, &dec.data);
        assert!(max <= 1e-3, "seed {seed}: bound violated after correction");
        if inj.applied && report.blocks_reexecuted > 0 {
            corrected_runs += 1;
            assert!(report.count(SdcKind::DecompCorrected) >= 1);
        }
    }
    assert!(corrected_runs > 10, "most injected faults should need re-execution");
}

#[test]
fn mode_b_single_flip_ftrsz_mostly_correct() {
    let f = field();
    let nb = n_blocks(f.dims, 8);
    let (mut correct, mut crash) = (0, 0);
    let n = 60;
    for seed in 0..n {
        let mut data = f.data.clone();
        let mut inj = ArenaFlip::new(seed, nb, 1);
        inj.apply_pre_checksum(&mut data);
        let o = run_and_classify(Engine::FaultTolerant, &data, f.dims, &cfg(), &mut inj);
        // classification against the PRISTINE field: pre-checksum flips are
        // the unavoidable failure window
        let o = match o {
            Outcome::Correct => {
                if ftsz::analysis::max_abs_err(&f.data, &data) > 1e-3 {
                    Outcome::Incorrect // flip predates checksums: silent
                } else {
                    Outcome::Correct
                }
            }
            other => other,
        };
        match o {
            Outcome::Correct => correct += 1,
            Outcome::Crash => crash += 1,
            _ => {}
        }
    }
    // paper Fig. 6(b): ~92% correct under 1 flip for ftrsz
    assert!(correct * 100 >= n * 80, "ftrsz correct {correct}/{n}");
    assert_eq!(crash, 0, "ftrsz must not crash under single flips");
}

#[test]
fn mode_b_flips_degrade_unprotected_sz_more() {
    let f = field();
    let nb = n_blocks(f.dims, 8);
    let n = 40;
    let run = |engine: Engine| {
        let mut correct = 0;
        for seed in 0..n {
            let mut data = f.data.clone();
            let mut inj = ArenaFlip::new(seed ^ 0xbeef, nb, 2);
            inj.apply_pre_checksum(&mut data);
            let o = run_and_classify(engine, &data, f.dims, &cfg(), &mut inj);
            if o == Outcome::Correct && ftsz::analysis::max_abs_err(&f.data, &data) <= 1e-3 {
                correct += 1;
            }
        }
        correct
    };
    let ft_ok = run(Engine::FaultTolerant);
    let rsz_ok = run(Engine::RandomAccess);
    assert!(
        ft_ok > rsz_ok,
        "ftrsz ({ft_ok}/{n}) must beat unprotected rsz ({rsz_ok}/{n}) under 2 flips"
    );
}

// ---------------------------------------------------------------------------
// ftxsz: the same campaigns against the fourth engine. The protection set
// differs (no prediction site, so no pred duplication), but the outcome
// contract is identical: corrected / clean-error / never silent.
// ---------------------------------------------------------------------------

#[test]
fn input_bitflips_always_corrected_by_ftxsz() {
    let f = field();
    for seed in 0..30 {
        let mut inj = InputBitFlip::new(seed, 1);
        let o = run_and_classify(Engine::UltraFastFT, &f.data, f.dims, &cfg(), &mut inj);
        assert_eq!(o, Outcome::Correct, "seed {seed}: ftxsz must correct input flips");
    }
}

#[test]
fn bin_bitflips_corrected_by_ftxsz() {
    // the leading-byte code arrays are checksum-protected exactly like the
    // quantization bins of ftrsz: a single flipped word is located and
    // repaired before serialization
    let f = field();
    let nb = n_blocks(f.dims, 8);
    for seed in 0..30 {
        let mut inj = BinBitFlip::new(seed, nb);
        let o = run_and_classify(Engine::UltraFastFT, &f.data, f.dims, &cfg(), &mut inj);
        assert_eq!(o, Outcome::Correct, "seed {seed}");
    }
}

#[test]
fn bin_bitflips_never_silent_on_unprotected_xsz() {
    // without checksums a flipped code either stays representable (decodes
    // off by whole quanta → Incorrect), overflows the block's byte width
    // (crash-equivalent abort at pack time), or lands in slack — but the
    // harness must classify every trial; silent-but-in-bound outcomes are
    // counted as Correct by definition
    let f = field();
    let nb = n_blocks(f.dims, 8);
    let mut bad = 0;
    let n = 40;
    for seed in 0..n {
        let mut inj = BinBitFlip::new(seed, nb);
        match run_and_classify(Engine::UltraFast, &f.data, f.dims, &cfg(), &mut inj) {
            Outcome::Correct => {}
            _ => bad += 1,
        }
    }
    assert!(bad > n / 4, "code flips should usually break unprotected xsz: {bad}/{n}");
}

#[test]
fn dcmp_faults_caught_by_duplication_on_ftxsz() {
    // the reconstruction is the one fragile computation left in this
    // engine; the instruction duplicate must catch first-evaluation faults
    use ftsz::inject::mode_a::DcmpFault;
    let f = field();
    let nb = n_blocks(f.dims, 8);
    let mut caught_runs = 0;
    for seed in 0..30 {
        let mut inj = DcmpFault::new(seed, nb, 512, false);
        let out = ftsz::compressor::xsz::compress_ft_with_hooks(&f.data, f.dims, &cfg(), &mut inj)
            .unwrap();
        if inj.applied && out.stats.dup_dcmp_catches >= 1 {
            caught_runs += 1;
        }
        let dec = ft::decompress(&out.archive).unwrap();
        let max = ftsz::analysis::max_abs_err(&f.data, &dec.data);
        assert!(max <= 1e-3, "seed {seed}: bound violated {max}");
    }
    // the target point is uniform over 0..512 but boundary blocks are
    // smaller, so only ~40% of seeds fire at all — require a solid share
    // of the fired ones, not a fixed majority of all seeds
    assert!(caught_runs > 5, "duplication caught only {caught_runs}/30 injected faults");
}

#[test]
fn decompression_faults_detected_and_corrected_on_ftxsz() {
    // §6.4.4 for the fourth engine: a transient decode-time fault in the
    // fixed-point reconstruction is detected by sum_dc and healed by
    // block re-execution — through the same destage verify stage as ftrsz
    let f = field();
    let nb = n_blocks(f.dims, 8);
    let bytes = ftsz::compressor::xsz::compress_ft(&f.data, f.dims, &cfg()).unwrap();
    let mut corrected_runs = 0;
    for seed in 0..30 {
        let mut inj = DecompFault::new(seed, nb, 512);
        let (dec, report) = ft::decompress_verbose(&bytes, &mut inj).unwrap();
        let max = ftsz::analysis::max_abs_err(&f.data, &dec.data);
        assert!(max <= 1e-3, "seed {seed}: bound violated after correction");
        if inj.applied && report.blocks_reexecuted > 0 {
            corrected_runs += 1;
            assert!(report.count(SdcKind::DecompCorrected) >= 1);
        }
    }
    // ~40% of seeds fire (see dcmp_faults_caught_by_duplication_on_ftxsz)
    assert!(corrected_runs > 5, "most injected faults should need re-execution");
}

#[test]
fn mode_b_single_flip_ftxsz_mostly_correct_and_never_silent() {
    // whole-memory injection over the xsz arena: input, leading-byte
    // codes, escape pool, and the constant/base table (the coeffs view).
    // The trichotomy: corrected, clean error, or — only for flips that
    // predate the checksums — a reclassified pre-checksum miss.
    let f = field();
    let nb = n_blocks(f.dims, 8);
    let (mut correct, mut crash) = (0, 0);
    let n = 60;
    for seed in 0..n {
        let mut data = f.data.clone();
        let mut inj = ArenaFlip::new(seed, nb, 1);
        inj.apply_pre_checksum(&mut data);
        let o = run_and_classify(Engine::UltraFastFT, &data, f.dims, &cfg(), &mut inj);
        let pre_checksum_hit = ftsz::analysis::max_abs_err(&f.data, &data) > 1e-3;
        match o {
            Outcome::Correct => {
                if !pre_checksum_hit {
                    correct += 1;
                }
            }
            Outcome::Crash => crash += 1,
            Outcome::Incorrect => {
                // a silent in-engine corruption would show up here with
                // pristine pre-run data — the outcome ftxsz must eliminate
                assert!(
                    pre_checksum_hit,
                    "seed {seed}: silent SDC from a post-checksum flip"
                );
            }
            Outcome::Detected => {}
        }
    }
    assert!(correct * 100 >= n * 80, "ftxsz correct {correct}/{n}");
    assert_eq!(crash, 0, "ftxsz must not crash under single flips");
}

#[test]
fn mode_b_flips_degrade_unprotected_xsz_more() {
    let f = field();
    let nb = n_blocks(f.dims, 8);
    let n = 40;
    let run = |engine: Engine| {
        let mut correct = 0;
        for seed in 0..n {
            let mut data = f.data.clone();
            let mut inj = ArenaFlip::new(seed ^ 0xbeef, nb, 2);
            inj.apply_pre_checksum(&mut data);
            let o = run_and_classify(engine, &data, f.dims, &cfg(), &mut inj);
            if o == Outcome::Correct && ftsz::analysis::max_abs_err(&f.data, &data) <= 1e-3 {
                correct += 1;
            }
        }
        correct
    };
    let ft_ok = run(Engine::UltraFastFT);
    let xsz_ok = run(Engine::UltraFast);
    assert!(
        ft_ok > xsz_ok,
        "ftxsz ({ft_ok}/{n}) must beat unprotected xsz ({xsz_ok}/{n}) under 2 flips"
    );
}

#[test]
fn mode_c_campaign_holds_the_trichotomy_for_ftxsz() {
    // archive-at-rest strikes against the new engine with parity on:
    // zero silent SDC and a high corrected rate, with observed repairs
    use ftsz::ft::parity::ParityParams;
    use ftsz::inject::mode_c::{campaign, ArchiveFault};
    use ftsz::inject::ArchiveOutcome;
    let f = synthetic::hurricane_field("t", Dims::d3(6, 8, 8), 9);
    let cfg = CompressionConfig::new(ErrorBound::Abs(1e-3))
        .with_block_size(4)
        .with_archive_parity(ParityParams::xor(64, 8));
    for engine in [Engine::UltraFast, Engine::UltraFastFT] {
        let tally =
            campaign(engine, &f.data, f.dims, &cfg, 150, ArchiveFault::BitFlip, 1, 1).unwrap();
        assert_eq!(
            tally.count(ArchiveOutcome::SilentSdc),
            0,
            "{}: silent SDC under single-bit archive faults",
            engine.name()
        );
        assert!(
            tally.corrected_rate() >= 0.95,
            "{}: corrected only {:.1}%",
            engine.name(),
            100.0 * tally.corrected_rate()
        );
        assert!(tally.parity_repaired_trials > 0, "{}: no repair observed", engine.name());
    }
}

// ---------------------------------------------------------------------------
// --xsz-bitpack under injection: the bit-granular block mode changes the
// packed wire format (tag 6), not the protection set — code checksums,
// duplication, and parity must hold the same trichotomy over it.
// ---------------------------------------------------------------------------

fn cfg_bitpack() -> CompressionConfig {
    cfg().with_xsz_bitpack(true)
}

#[test]
fn bin_bitflips_corrected_by_ftxsz_bitpack() {
    // a flipped code word is located by the code checksum and repaired
    // before the w-bit pack ever sees it
    let f = field();
    let nb = n_blocks(f.dims, 8);
    for seed in 0..30 {
        let mut inj = BinBitFlip::new(seed, nb);
        let o = run_and_classify(Engine::UltraFastFT, &f.data, f.dims, &cfg_bitpack(), &mut inj);
        assert_eq!(o, Outcome::Correct, "seed {seed}");
    }
}

#[test]
fn bin_bitflips_never_silent_on_xsz_bitpack() {
    // without checksums a flipped code either decodes off by whole quanta
    // (Incorrect), overflows the block's bit width (clean abort at pack
    // time — the all-ones escape cap), or lands in slack; never silent
    let f = field();
    let nb = n_blocks(f.dims, 8);
    let mut bad = 0;
    let n = 40;
    for seed in 0..n {
        let mut inj = BinBitFlip::new(seed, nb);
        match run_and_classify(Engine::UltraFast, &f.data, f.dims, &cfg_bitpack(), &mut inj) {
            Outcome::Correct => {}
            _ => bad += 1,
        }
    }
    assert!(bad > n / 4, "code flips should usually break unprotected bitpack xsz: {bad}/{n}");
}

#[test]
fn mode_b_single_flip_ftxsz_bitpack_mostly_correct_and_never_silent() {
    let f = field();
    let nb = n_blocks(f.dims, 8);
    let (mut correct, mut crash) = (0, 0);
    let n = 60;
    for seed in 0..n {
        let mut data = f.data.clone();
        let mut inj = ArenaFlip::new(seed, nb, 1);
        inj.apply_pre_checksum(&mut data);
        let o = run_and_classify(Engine::UltraFastFT, &data, f.dims, &cfg_bitpack(), &mut inj);
        let pre_checksum_hit = ftsz::analysis::max_abs_err(&f.data, &data) > 1e-3;
        match o {
            Outcome::Correct => {
                if !pre_checksum_hit {
                    correct += 1;
                }
            }
            Outcome::Crash => crash += 1,
            Outcome::Incorrect => {
                assert!(
                    pre_checksum_hit,
                    "seed {seed}: silent SDC from a post-checksum flip (bitpack)"
                );
            }
            Outcome::Detected => {}
        }
    }
    assert!(correct * 100 >= n * 80, "ftxsz bitpack correct {correct}/{n}");
    assert_eq!(crash, 0, "ftxsz bitpack must not crash under single flips");
}

#[test]
fn mode_c_campaign_holds_the_trichotomy_for_ftxsz_bitpack() {
    // archive-at-rest strikes over tag-6 payload bytes: zero silent SDC,
    // high corrected rate, observed parity repairs
    use ftsz::ft::parity::ParityParams;
    use ftsz::inject::mode_c::{campaign, ArchiveFault};
    use ftsz::inject::ArchiveOutcome;
    let f = synthetic::hurricane_field("t", Dims::d3(6, 8, 8), 9);
    let cfg = CompressionConfig::new(ErrorBound::Abs(1e-3))
        .with_block_size(4)
        .with_xsz_bitpack(true)
        .with_archive_parity(ParityParams::xor(64, 8));
    for engine in [Engine::UltraFast, Engine::UltraFastFT] {
        let tally =
            campaign(engine, &f.data, f.dims, &cfg, 150, ArchiveFault::BitFlip, 1, 1).unwrap();
        assert_eq!(
            tally.count(ArchiveOutcome::SilentSdc),
            0,
            "{} bitpack: silent SDC under single-bit archive faults",
            engine.name()
        );
        assert!(
            tally.corrected_rate() >= 0.95,
            "{} bitpack: corrected only {:.1}%",
            engine.name(),
            100.0 * tally.corrected_rate()
        );
        assert!(
            tally.parity_repaired_trials > 0,
            "{} bitpack: no repair observed",
            engine.name()
        );
    }
}

#[test]
fn ft_decompress_verbose_clean_on_uninjected_data() {
    let f = field();
    let bytes = ft::compress(&f.data, f.dims, &cfg()).unwrap();
    struct Clean;
    impl DecompressHooks for Clean {}
    let (_, report) = ft::decompress_verbose(&bytes, &mut Clean).unwrap();
    assert!(report.is_clean());
    let _ = NoHooks; // silence unused import lint paths
}
