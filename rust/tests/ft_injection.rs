//! Integration: the paper's core claim — ftrsz survives SDCs that break
//! unprotected SZ (Table 3, Fig. 6, §6.4.4).

use ftsz::compressor::engine::{DecompressHooks, NoHooks};
use ftsz::compressor::{CompressionConfig, ErrorBound};
use ftsz::data::{synthetic, Dims};
use ftsz::ft;
use ftsz::ft::report::SdcKind;
use ftsz::inject::mode_a::{BinBitFlip, DecompFault, EstimationFault, InputBitFlip, PredFault};
use ftsz::inject::mode_b::ArenaFlip;
use ftsz::inject::{run_and_classify, Engine, Outcome};

fn field() -> ftsz::data::Field {
    synthetic::hurricane_field("t", Dims::d3(10, 20, 20), 77)
}

fn cfg() -> CompressionConfig {
    CompressionConfig::new(ErrorBound::Abs(1e-3)).with_block_size(8)
}

fn n_blocks(dims: Dims, b: usize) -> usize {
    let (d, r, c) = dims.as_3d();
    d.div_ceil(b) * r.div_ceil(b) * c.div_ceil(b)
}

#[test]
fn input_bitflips_always_corrected_by_ftrsz() {
    let f = field();
    for seed in 0..30 {
        let mut inj = InputBitFlip::new(seed, 1);
        let o = run_and_classify(Engine::FaultTolerant, &f.data, f.dims, &cfg(), &mut inj);
        assert_eq!(o, Outcome::Correct, "seed {seed}: ftrsz must correct input flips");
    }
}

#[test]
fn input_bitflips_often_break_unprotected_sz() {
    let f = field();
    let mut incorrect = 0;
    let n = 40;
    for seed in 0..n {
        let mut inj = InputBitFlip::new(seed, 1);
        let o = run_and_classify(Engine::RandomAccess, &f.data, f.dims, &cfg(), &mut inj);
        if o != Outcome::Correct {
            incorrect += 1;
        }
    }
    // high exponent/sign bits corrupt the value beyond the bound; the paper
    // sees ~40-50% of unprotected runs fail — require a nonzero failure
    // rate here (the exact share depends on bit position distribution)
    assert!(incorrect > n / 5, "only {incorrect}/{n} unprotected runs failed");
}

#[test]
fn bin_bitflips_corrected_by_ftrsz() {
    let f = field();
    let nb = n_blocks(f.dims, 8);
    for seed in 0..30 {
        let mut inj = BinBitFlip::new(seed, nb);
        let o = run_and_classify(Engine::FaultTolerant, &f.data, f.dims, &cfg(), &mut inj);
        assert_eq!(o, Outcome::Correct, "seed {seed}");
    }
}

#[test]
fn bin_bitflips_crash_or_break_unprotected_engines() {
    let f = field();
    let nb = n_blocks(f.dims, 8);
    let mut bad = 0;
    let mut crashes = 0;
    let n = 40;
    for seed in 0..n {
        let mut inj = BinBitFlip::new(seed, nb);
        match run_and_classify(Engine::RandomAccess, &f.data, f.dims, &cfg(), &mut inj) {
            Outcome::Correct => {}
            Outcome::Crash => {
                crashes += 1;
                bad += 1;
            }
            _ => bad += 1,
        }
    }
    assert!(bad > n / 4, "bin flips should usually break rsz: {bad}/{n}");
    assert!(crashes > 0, "high-bit flips should crash (out-of-table codes)");
}

#[test]
fn estimation_faults_never_affect_correctness() {
    // §4.1.1: computation errors in regression/sampling only cost ratio
    let f = field();
    let nb = n_blocks(f.dims, 8);
    for engine in [Engine::RandomAccess, Engine::FaultTolerant] {
        for seed in 0..15 {
            let mut inj = EstimationFault::new(seed, nb, 3);
            let o = run_and_classify(engine, &f.data, f.dims, &cfg(), &mut inj);
            assert_eq!(o, Outcome::Correct, "engine {} seed {seed}", engine.name());
        }
    }
}

#[test]
fn pred_faults_caught_by_duplication() {
    let f = field();
    let nb = n_blocks(f.dims, 8);
    for seed in 0..30 {
        let mut inj = PredFault::new(seed, nb, 512);
        let out = ft::compress_with_hooks(&f.data, f.dims, &cfg(), &mut inj).unwrap();
        if inj.applied {
            assert!(
                out.stats.dup_pred_catches >= 1,
                "seed {seed}: duplication must catch the pred fault"
            );
        }
        let dec = ft::decompress(&out.archive).unwrap();
        let max = ftsz::analysis::max_abs_err(&f.data, &dec.data);
        assert!(max <= 1e-3, "seed {seed}: bound violated {max}");
    }
}

#[test]
fn pred_faults_can_silently_break_unprotected_rsz() {
    let f = field();
    let nb = n_blocks(f.dims, 8);
    let mut incorrect = 0;
    for seed in 0..60 {
        let mut inj = PredFault::new(seed, nb, 512);
        let o = run_and_classify(Engine::RandomAccess, &f.data, f.dims, &cfg(), &mut inj);
        if o == Outcome::Incorrect {
            incorrect += 1;
        }
    }
    // Case 1 Situation 2 (§4.1.2): some flips stay under the quantization
    // range and silently poison the decompression
    assert!(incorrect > 0, "expected at least one silent corruption");
}

#[test]
fn decompression_faults_detected_and_corrected() {
    // §6.4.4: inject one computation error per decompression; 100% detected
    // by sum_dc and corrected by block re-execution
    let f = field();
    let nb = n_blocks(f.dims, 8);
    let bytes = ft::compress(&f.data, f.dims, &cfg()).unwrap();
    let mut corrected_runs = 0;
    for seed in 0..30 {
        let mut inj = DecompFault::new(seed, nb, 512);
        let (dec, report) = ft::decompress_verbose(&bytes, &mut inj).unwrap();
        let max = ftsz::analysis::max_abs_err(&f.data, &dec.data);
        assert!(max <= 1e-3, "seed {seed}: bound violated after correction");
        if inj.applied && report.blocks_reexecuted > 0 {
            corrected_runs += 1;
            assert!(report.count(SdcKind::DecompCorrected) >= 1);
        }
    }
    assert!(corrected_runs > 10, "most injected faults should need re-execution");
}

#[test]
fn mode_b_single_flip_ftrsz_mostly_correct() {
    let f = field();
    let nb = n_blocks(f.dims, 8);
    let (mut correct, mut crash) = (0, 0);
    let n = 60;
    for seed in 0..n {
        let mut data = f.data.clone();
        let mut inj = ArenaFlip::new(seed, nb, 1);
        inj.apply_pre_checksum(&mut data);
        let o = run_and_classify(Engine::FaultTolerant, &data, f.dims, &cfg(), &mut inj);
        // classification against the PRISTINE field: pre-checksum flips are
        // the unavoidable failure window
        let o = match o {
            Outcome::Correct => {
                if ftsz::analysis::max_abs_err(&f.data, &data) > 1e-3 {
                    Outcome::Incorrect // flip predates checksums: silent
                } else {
                    Outcome::Correct
                }
            }
            other => other,
        };
        match o {
            Outcome::Correct => correct += 1,
            Outcome::Crash => crash += 1,
            _ => {}
        }
    }
    // paper Fig. 6(b): ~92% correct under 1 flip for ftrsz
    assert!(correct * 100 >= n * 80, "ftrsz correct {correct}/{n}");
    assert_eq!(crash, 0, "ftrsz must not crash under single flips");
}

#[test]
fn mode_b_flips_degrade_unprotected_sz_more() {
    let f = field();
    let nb = n_blocks(f.dims, 8);
    let n = 40;
    let run = |engine: Engine| {
        let mut correct = 0;
        for seed in 0..n {
            let mut data = f.data.clone();
            let mut inj = ArenaFlip::new(seed ^ 0xbeef, nb, 2);
            inj.apply_pre_checksum(&mut data);
            let o = run_and_classify(engine, &data, f.dims, &cfg(), &mut inj);
            if o == Outcome::Correct && ftsz::analysis::max_abs_err(&f.data, &data) <= 1e-3 {
                correct += 1;
            }
        }
        correct
    };
    let ft_ok = run(Engine::FaultTolerant);
    let rsz_ok = run(Engine::RandomAccess);
    assert!(
        ft_ok > rsz_ok,
        "ftrsz ({ft_ok}/{n}) must beat unprotected rsz ({rsz_ok}/{n}) under 2 flips"
    );
}

#[test]
fn ft_decompress_verbose_clean_on_uninjected_data() {
    let f = field();
    let bytes = ft::compress(&f.data, f.dims, &cfg()).unwrap();
    struct Clean;
    impl DecompressHooks for Clean {}
    let (_, report) = ft::decompress_verbose(&bytes, &mut Clean).unwrap();
    assert!(report.is_clean());
    let _ = NoHooks; // silence unused import lint paths
}
