//! Golden-bytes regression tests for the stage-graph refactor: the
//! archive format is a contract, and refactoring the codec core must not
//! move a single bit of it.
//!
//! Two layers of proof:
//!
//! 1. **Retained pre-refactor reference** (`legacy` module below): a
//!    faithful copy of the sequential monolith the stage graph replaced —
//!    rsz/ftrsz (`compress_core` with no-op hooks) and classic — built
//!    only from the crate's public leaf APIs. Every engine × format
//!    version × {1, 2, 4} workers must reproduce its bytes exactly.
//! 2. **Committed fixtures** (`rust/tests/data/*.bin`): blessed archive
//!    bytes checked in as test data, so *future* refactors are compared
//!    against bytes produced by *this* PR's code, not just against an
//!    in-tree reference that might be refactored alongside. Bless with
//!    `FTSZ_BLESS=1 cargo test --test golden_bytes` and commit the files;
//!    when a fixture is absent the comparison is skipped with a note (the
//!    legacy-reference layer still runs).

use ftsz::compressor::{classic, engine, CompressionConfig, ErrorBound};
use ftsz::data::{synthetic, Dims};
use ftsz::ft;
use ftsz::ft::parity::ParityParams;

/// A small but predictor-diverse field: smooth regions (regression wins)
/// and vortex structure (Lorenzo wins).
fn field() -> (Vec<f32>, Dims) {
    let f = synthetic::hurricane_field("t", Dims::d3(6, 10, 10), 3);
    (f.data, f.dims)
}

fn cfg(parity: bool) -> CompressionConfig {
    let c = CompressionConfig::new(ErrorBound::Abs(1e-3)).with_block_size(4);
    if parity {
        c.with_archive_parity(ParityParams::xor(64, 8))
    } else {
        c
    }
}

/// Compare against a committed fixture, or bless it under `FTSZ_BLESS=1`.
fn fixture_check(name: &str, bytes: &[u8]) {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/data");
    let path = dir.join(name);
    if std::env::var("FTSZ_BLESS").ok().as_deref() == Some("1") {
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(&path, bytes).unwrap();
        return;
    }
    match std::fs::read(&path) {
        Ok(stored) => assert_eq!(
            stored, bytes,
            "golden fixture {name} drifted — the archive bytes changed across PRs"
        ),
        Err(_) => eprintln!(
            "note: golden fixture {name} absent; bless with \
             `FTSZ_BLESS=1 cargo test --test golden_bytes` and commit rust/tests/data"
        ),
    }
}

/// The core assertion: `new` produces `legacy`'s bytes at 1, 2 and 4
/// workers, for v1 and v2, and the fixture layer agrees.
fn assert_golden(
    name: &str,
    legacy: impl Fn(&[f32], Dims, &CompressionConfig) -> Vec<u8>,
    new: impl Fn(&[f32], Dims, &CompressionConfig) -> Vec<u8>,
) {
    let (data, dims) = field();
    for parity in [false, true] {
        let version = if parity { "v2" } else { "v1" };
        let base = cfg(parity);
        let want = legacy(&data, dims, &base);
        for w in [1usize, 2, 4] {
            let c = base.clone().with_workers(w);
            let got = new(&data, dims, &c);
            assert_eq!(
                got, want,
                "{name} {version} at {w} workers differs from the pre-refactor reference"
            );
            // the pipelined and plain sequential drivers must agree too
            let c_off = base.clone().with_workers(w).with_stage_overlap(false);
            assert_eq!(new(&data, dims, &c_off), want, "{name} {version} overlap-off");
        }
        fixture_check(&format!("golden_{name}_{version}.bin"), &want);
    }
}

#[test]
fn rsz_bytes_match_pre_refactor_reference() {
    assert_golden(
        "rsz",
        |d, dims, c| legacy::rsz_ftrsz_compress(d, dims, c, false),
        |d, dims, c| engine::compress(d, dims, c).unwrap(),
    );
}

#[test]
fn ftrsz_bytes_match_pre_refactor_reference() {
    assert_golden(
        "ftrsz",
        |d, dims, c| legacy::rsz_ftrsz_compress(d, dims, c, true),
        |d, dims, c| ft::compress(d, dims, c).unwrap(),
    );
}

#[test]
fn classic_bytes_match_pre_refactor_reference() {
    assert_golden(
        "sz",
        legacy::classic_compress,
        |d, dims, c| classic::compress(d, dims, c).unwrap(),
    );
}

#[test]
fn streaming_bytes_match_pre_refactor_reference() {
    // Chain shape 3 rides the same golden contract: the slab-streaming
    // compress path must emit the pre-refactor reference bytes on every
    // driver (sequential / pipelined / parallel), for v1 and v2. The
    // xsz pair has no pre-refactor monolith, so its streaming bytes are
    // pinned to the in-memory path plus a blessable fixture.
    use ftsz::compressor::stream::SliceSource;
    use ftsz::inject::Engine;
    let (data, dims) = field();
    for parity in [false, true] {
        let version = if parity { "v2" } else { "v1" };
        let base = cfg(parity);
        let cases: Vec<(&str, &dyn ftsz::compressor::stage::BlockCodec, Vec<u8>)> = vec![
            (
                "rsz",
                Engine::RandomAccess.codec(),
                legacy::rsz_ftrsz_compress(&data, dims, &base, false),
            ),
            (
                "ftrsz",
                Engine::FaultTolerant.codec(),
                legacy::rsz_ftrsz_compress(&data, dims, &base, true),
            ),
            ("sz", Engine::Classic.codec(), legacy::classic_compress(&data, dims, &base)),
        ];
        for (name, codec, want) in &cases {
            for w in [1usize, 2, 4] {
                for overlap in [true, false] {
                    let c = base.clone().with_workers(w).with_stage_overlap(overlap);
                    let mut src = SliceSource::new(dims, &data).unwrap();
                    let got = codec.compress_stream(&mut src, &c).unwrap();
                    assert_eq!(
                        &got, want,
                        "{name} {version} streaming at {w} workers (overlap={overlap}) \
                         differs from the pre-refactor reference"
                    );
                }
            }
        }
        for e in [Engine::UltraFast, Engine::UltraFastFT] {
            let codec = e.codec();
            let want = codec.compress(&data, dims, &base).unwrap();
            for w in [1usize, 2, 4] {
                for overlap in [true, false] {
                    let c = base.clone().with_workers(w).with_stage_overlap(overlap);
                    let mut src = SliceSource::new(dims, &data).unwrap();
                    let got = codec.compress_stream(&mut src, &c).unwrap();
                    assert_eq!(
                        got,
                        want,
                        "{} {version} streaming at {w} workers (overlap={overlap}) \
                         differs from the in-memory path",
                        e.name()
                    );
                }
            }
            fixture_check(&format!("golden_stream_{}_{version}.bin", e.name()), &want);
        }
    }
}

#[test]
fn legacy_reference_archives_decode_within_bound() {
    // sanity for the reference itself: its bytes are real archives
    let (data, dims) = field();
    let rsz = legacy::rsz_ftrsz_compress(&data, dims, &cfg(false), false);
    let dec = engine::decompress(&rsz).unwrap();
    assert!(ftsz::analysis::max_abs_err(&data, &dec.data) <= 1e-3);
    let ftr = legacy::rsz_ftrsz_compress(&data, dims, &cfg(true), true);
    let dec = ft::decompress(&ftr).unwrap();
    assert!(ftsz::analysis::max_abs_err(&data, &dec.data) <= 1e-3);
    let sz = legacy::classic_compress(&data, dims, &cfg(false));
    let dec = classic::decompress(&sz).unwrap();
    assert!(ftsz::analysis::max_abs_err(&data, &dec.data) <= 1e-3);
}

/// Faithful copies of the pre-refactor (PR 2) compression paths, with the
/// injection hooks specialized to no-ops — byte-for-byte the code the
/// stage graph replaced, built on the crate's public leaf APIs only. Do
/// not "clean this up": its value is that it does NOT evolve with the
/// production code.
mod legacy {
    use ftsz::compressor::block::BlockGrid;
    use ftsz::compressor::format::{BlockMeta, BlockPayload, Header, Writer};
    use ftsz::compressor::huffman::HuffmanTable;
    use ftsz::compressor::lorenzo::{self, GridView};
    use ftsz::compressor::quantize::{Quantizer, UNPREDICTABLE};
    use ftsz::compressor::sampling::{self, Selection};
    use ftsz::compressor::{regression, CompressionConfig, Predictor};
    use ftsz::data::Dims;
    use ftsz::ft::checksum::{self, Correction};
    use ftsz::ft::duplicate::protected_eval;
    use ftsz::util::bits::BitWriter;

    /// Pre-refactor `compress_block` (hooks = no-ops).
    #[allow(clippy::too_many_arguments)]
    fn compress_block(
        block: &[f32],
        shape: (usize, usize, usize),
        sel: &Selection,
        q: &Quantizer,
        protect: bool,
        codes: &mut Vec<u32>,
        unpred: &mut Vec<f32>,
        dcmp_block: &mut Vec<f32>,
    ) {
        let (nz, ny, nx) = shape;
        dcmp_block.clear();
        dcmp_block.resize(block.len(), 0.0);
        let mut catches = 0u64;
        let mut p = 0usize;
        for z in 0..nz {
            for y in 0..ny {
                for x in 0..nx {
                    let val = block[p];
                    let pred = match sel.predictor {
                        Predictor::Lorenzo if z > 0 && y > 0 && x > 0 => {
                            let (sy, sz) = (nx, ny * nx);
                            let first = lorenzo::predict_interior_dense(dcmp_block, p, sy, sz);
                            if protect {
                                let dup =
                                    lorenzo::predict_interior_dense_dup(dcmp_block, p, sy, sz);
                                protected_eval(
                                    first,
                                    dup,
                                    || lorenzo::predict_interior_dense(dcmp_block, p, sy, sz),
                                    &mut catches,
                                )
                            } else {
                                first
                            }
                        }
                        Predictor::Lorenzo => {
                            let view = GridView::dense(dcmp_block, shape);
                            let first = lorenzo::predict(&view, z, y, x);
                            if protect {
                                let dup = lorenzo::predict_dup(&view, z, y, x);
                                protected_eval(
                                    first,
                                    dup,
                                    || lorenzo::predict(&view, z, y, x),
                                    &mut catches,
                                )
                            } else {
                                first
                            }
                        }
                        Predictor::Regression => {
                            let c = &sel.coeffs;
                            let first = regression::predict(c, z, y, x);
                            if protect {
                                let dup = regression::predict_dup(c, z, y, x);
                                protected_eval(
                                    first,
                                    dup,
                                    || regression::predict(c, z, y, x),
                                    &mut catches,
                                )
                            } else {
                                first
                            }
                        }
                        Predictor::DualQuant => unreachable!("sampling never selects dual-quant"),
                    };
                    match q.quantize(val, pred) {
                        Some((code, dcmp_raw)) => {
                            let dcmp = if protect {
                                let dup = q.reconstruct_dup(code, pred);
                                protected_eval(
                                    dcmp_raw,
                                    dup,
                                    || q.reconstruct(code, pred),
                                    &mut catches,
                                )
                            } else {
                                dcmp_raw
                            };
                            if q.within_bound(val, dcmp) {
                                codes.push(code);
                                dcmp_block[p] = dcmp;
                            } else {
                                codes.push(UNPREDICTABLE);
                                unpred.push(val);
                                dcmp_block[p] = val;
                            }
                        }
                        None => {
                            codes.push(UNPREDICTABLE);
                            unpred.push(val);
                            dcmp_block[p] = val;
                        }
                    }
                    p += 1;
                }
            }
        }
    }

    /// Pre-refactor sequential `compress_core` (hooks = no-ops):
    /// `ft = false` is rsz, `ft = true` is ftrsz (protect + checksums).
    pub fn rsz_ftrsz_compress(
        data: &[f32],
        dims: Dims,
        cfg: &CompressionConfig,
        ft: bool,
    ) -> Vec<u8> {
        let protect = ft;
        let bound = cfg.error_bound.absolute(data);
        let q = Quantizer::new(bound, cfg.quant_radius);
        let grid = BlockGrid::new(dims, cfg.block_size).unwrap();
        let n_blocks = grid.n_blocks();
        let input = data.to_vec();

        // Alg.1 l.1-5: per-block input checksums
        let mut in_sums = Vec::new();
        let mut scratch = Vec::new();
        if ft {
            for bi in 0..n_blocks {
                grid.extract(&input, bi, &mut scratch);
                in_sums.push(checksum::checksum_f32(&scratch));
            }
        }

        // Alg.1 l.6-9: estimation + selection
        let mut selections: Vec<Selection> = Vec::with_capacity(n_blocks);
        for bi in 0..n_blocks {
            grid.extract(&input, bi, &mut scratch);
            let shape = grid.extent(bi).shape;
            let (coeffs, e_lor, e_reg) = sampling::estimate(&scratch, shape);
            selections.push(sampling::select(&scratch, shape, cfg.predictor, coeffs, e_lor, e_reg));
        }

        // Alg.1 l.10-32: main loop
        let mut codes: Vec<u32> = Vec::with_capacity(data.len());
        let mut code_block_offsets = vec![0usize];
        let mut unpred: Vec<f32> = Vec::new();
        let mut unpred_counts: Vec<u32> = Vec::with_capacity(n_blocks);
        let mut q_sums = Vec::with_capacity(n_blocks);
        let mut dc_sums: Vec<u64> = Vec::with_capacity(n_blocks);
        let all_coeffs: Vec<[f32; 4]> = selections.iter().map(|s| s.coeffs).collect();
        let mut dcmp_block: Vec<f32> = Vec::new();
        for bi in 0..n_blocks {
            grid.extract(&input, bi, &mut scratch);
            let shape = grid.extent(bi).shape;
            if ft {
                // l.11: clean input verifies clean — kept for fidelity
                assert!(matches!(
                    checksum::verify_correct_f32(&mut scratch, in_sums[bi]),
                    Correction::Clean
                ));
            }
            let sel = selections[bi];
            let unpred_before = unpred.len();
            let code_base = codes.len();
            compress_block(
                &scratch,
                shape,
                &sel,
                &q,
                protect,
                &mut codes,
                &mut unpred,
                &mut dcmp_block,
            );
            unpred_counts.push((unpred.len() - unpred_before) as u32);
            code_block_offsets.push(codes.len());
            if ft {
                q_sums.push(checksum::checksum_u32(&codes[code_base..]));
                dc_sums.push(checksum::checksum_f32(&dcmp_block).sum);
            }
        }

        // l.33-35: verify bins before the tree build
        if ft {
            for bi in 0..n_blocks {
                let span = &mut codes[code_block_offsets[bi]..code_block_offsets[bi + 1]];
                assert!(matches!(
                    checksum::verify_correct_u32(span, q_sums[bi]),
                    Correction::Clean
                ));
            }
        }

        // l.36-38: global table + per-block encode
        let n_symbols = q.n_symbols();
        let mut freqs = vec![0u64; n_symbols];
        for &c in &codes {
            freqs[c as usize] += 1;
        }
        let table = HuffmanTable::from_frequencies(&freqs).unwrap();
        let mut blocks = Vec::with_capacity(n_blocks);
        for bi in 0..n_blocks {
            let span = &codes[code_block_offsets[bi]..code_block_offsets[bi + 1]];
            let mut w = BitWriter::with_capacity(span.len() / 4 + 8);
            for &c in span {
                table.encode(&mut w, c).unwrap();
            }
            let payload_bits = w.bit_len() as u64;
            let sel = &selections[bi];
            blocks.push(BlockPayload {
                meta: BlockMeta {
                    predictor: sel.predictor,
                    coeffs: all_coeffs[bi],
                    n_unpred: unpred_counts[bi],
                    payload_bits,
                },
                bytes: w.finish(),
            });
        }

        Writer {
            header: Header {
                flags: 0,
                dims,
                block_size: cfg.block_size as u32,
                quant_radius: cfg.quant_radius,
                error_bound: bound,
                n_blocks: n_blocks as u64,
            },
            table: &table,
            blocks,
            classic_payload: None,
            unpred: &unpred,
            sum_dc: if ft { Some(&dc_sums) } else { None },
            zstd_level: cfg.zstd_level,
            payload_zstd: cfg.payload_zstd,
            parity: cfg.archive_parity,
            unpred_body: None,
        }
        .write()
        .unwrap()
    }

    /// Pre-refactor `classic::compress` (hooks = no-ops).
    pub fn classic_compress(data: &[f32], dims: Dims, cfg: &CompressionConfig) -> Vec<u8> {
        let bound = cfg.error_bound.absolute(data);
        let q = Quantizer::new(bound, cfg.quant_radius);
        let grid = BlockGrid::new(dims, cfg.block_size).unwrap();
        let n_blocks = grid.n_blocks();
        let shape3 = dims.as_3d();
        let input = data.to_vec();

        let mut selections: Vec<Selection> = Vec::with_capacity(n_blocks);
        let mut scratch = Vec::new();
        for bi in 0..n_blocks {
            grid.extract(&input, bi, &mut scratch);
            let shape = grid.extent(bi).shape;
            let (coeffs, e_lor, e_reg) = sampling::estimate(&scratch, shape);
            selections.push(sampling::select(&scratch, shape, cfg.predictor, coeffs, e_lor, e_reg));
        }

        let mut dcmp = vec![0.0f32; data.len()];
        let mut codes: Vec<u32> = Vec::with_capacity(data.len());
        let mut unpred: Vec<f32> = Vec::new();
        let mut metas: Vec<BlockMeta> = Vec::with_capacity(n_blocks);
        let (_, ry, rx) = shape3;
        for bi in 0..n_blocks {
            let e = grid.extent(bi);
            let sel = selections[bi];
            let unpred_before = unpred.len();
            for z in 0..e.shape.0 {
                for y in 0..e.shape.1 {
                    for x in 0..e.shape.2 {
                        let (gz, gy, gx) = (e.origin.0 + z, e.origin.1 + y, e.origin.2 + x);
                        let gidx = (gz * ry + gy) * rx + gx;
                        let val = input[gidx];
                        let pred = match sel.predictor {
                            Predictor::Lorenzo => {
                                let view = GridView::dense(&dcmp, shape3);
                                lorenzo::predict(&view, gz, gy, gx)
                            }
                            Predictor::Regression => regression::predict(&sel.coeffs, z, y, x),
                            Predictor::DualQuant => {
                                unreachable!("classic never selects dual-quant")
                            }
                        };
                        match q.quantize(val, pred) {
                            Some((code, d)) => {
                                if q.within_bound(val, d) {
                                    codes.push(code);
                                    dcmp[gidx] = d;
                                } else {
                                    codes.push(UNPREDICTABLE);
                                    unpred.push(val);
                                    dcmp[gidx] = val;
                                }
                            }
                            None => {
                                codes.push(UNPREDICTABLE);
                                unpred.push(val);
                                dcmp[gidx] = val;
                            }
                        }
                    }
                }
            }
            metas.push(BlockMeta {
                predictor: sel.predictor,
                coeffs: sel.coeffs,
                n_unpred: (unpred.len() - unpred_before) as u32,
                payload_bits: 0,
            });
        }

        let n_symbols = q.n_symbols();
        let mut freqs = vec![0u64; n_symbols];
        for &c in &codes {
            freqs[c as usize] += 1;
        }
        let table = HuffmanTable::from_frequencies(&freqs).unwrap();
        let mut w = BitWriter::with_capacity(codes.len() / 4 + 8);
        for &c in &codes {
            table.encode(&mut w, c).unwrap();
        }
        metas[0].payload_bits = w.bit_len() as u64;
        let stream = w.finish();

        Writer {
            header: Header {
                flags: 0,
                dims,
                block_size: cfg.block_size as u32,
                quant_radius: cfg.quant_radius,
                error_bound: bound,
                n_blocks: n_blocks as u64,
            },
            table: &table,
            blocks: vec![],
            classic_payload: Some((metas, stream)),
            unpred: &unpred,
            sum_dc: None,
            zstd_level: cfg.zstd_level,
            payload_zstd: false,
            parity: cfg.archive_parity,
            unpred_body: None,
        }
        .write()
        .unwrap()
    }
}
