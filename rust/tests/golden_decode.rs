//! Golden decode: the decode stage graph's three drivers are
//! bit-interchangeable. Decoded `f32` output must be identical across
//! sequential / pipelined / block-parallel drivers × {1, 2, 4} workers ×
//! v1/v2 archives × engines — for full, verified, region and
//! verified-region decompression — and verified-region decode must detect
//! exactly the injected faults full verified decode detects.

use ftsz::compressor::block::Region;
use ftsz::compressor::destage::{self, DecodeDriver};
use ftsz::compressor::{classic, engine, CompressionConfig, ErrorBound, Parallelism};
use ftsz::data::{synthetic, Dims, Field};
use ftsz::ft::{self, parity::ParityParams};
use ftsz::inject::Engine;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The expected region values: the `region` slice of a full decode.
fn region_slice(full: &[f32], dims: Dims, region: Region) -> Vec<f32> {
    let (_, ry, rx) = dims.as_3d();
    let mut want = Vec::with_capacity(region.len());
    for z in 0..region.shape.0 {
        for y in 0..region.shape.1 {
            for x in 0..region.shape.2 {
                let g = ((region.origin.0 + z) * ry + region.origin.1 + y) * rx
                    + region.origin.2
                    + x;
                want.push(full[g]);
            }
        }
    }
    want
}

fn field() -> Field {
    synthetic::hurricane_field("t", Dims::d3(10, 16, 16), 311)
}

fn cfg(parity: bool) -> CompressionConfig {
    let c = CompressionConfig::new(ErrorBound::Abs(1e-3)).with_block_size(6);
    if parity {
        c.with_archive_parity(ParityParams::xor(64, 8))
    } else {
        c
    }
}

const DRIVERS: [DecodeDriver; 4] = [
    DecodeDriver::Sequential,
    DecodeDriver::Pipelined,
    DecodeDriver::Parallel(2),
    DecodeDriver::Parallel(4),
];

#[test]
fn full_decode_bit_identical_across_drivers_engines_and_formats() {
    let f = field();
    for parity in [false, true] {
        for e in [
            Engine::RandomAccess,
            Engine::FaultTolerant,
            Engine::UltraFast,
            Engine::UltraFastFT,
        ] {
            let bytes = e.codec().compress(&f.data, f.dims, &cfg(parity)).unwrap();
            let verify = e.codec().supports_verify();
            let reference =
                destage::decode_with_driver(&bytes, false, None, DecodeDriver::Sequential)
                    .unwrap();
            for driver in DRIVERS {
                for v in [false, verify] {
                    let got = destage::decode_with_driver(&bytes, v, None, driver).unwrap();
                    assert_eq!(
                        bits(&got.data),
                        bits(&reference.data),
                        "{} parity={parity} verify={v} {driver:?}",
                        e.name()
                    );
                }
            }
            // the public worker-count knob must agree with the drivers too
            for w in [1usize, 2, 4] {
                let got =
                    e.codec().decompress(&bytes, Parallelism::from_workers(w)).unwrap();
                assert_eq!(
                    bits(&got.data),
                    bits(&reference.data),
                    "{} parity={parity} w={w}",
                    e.name()
                );
            }
        }
        // classic is not part of the destage chain (single dependent
        // stream) but must keep decoding identically through its codec
        let bytes = Engine::Classic.codec().compress(&f.data, f.dims, &cfg(parity)).unwrap();
        let a = classic::decompress(&bytes).unwrap();
        let b = Engine::Classic.codec().decompress(&bytes, Parallelism::Fixed(4)).unwrap();
        assert_eq!(bits(&a.data), bits(&b.data));
    }
}

#[test]
fn region_decode_bit_identical_across_drivers_and_matches_full_slice() {
    let f = field();
    let region = Region { origin: (3, 4, 2), shape: (5, 9, 11) };
    for parity in [false, true] {
        for e in [
            Engine::RandomAccess,
            Engine::FaultTolerant,
            Engine::UltraFast,
            Engine::UltraFastFT,
        ] {
            let bytes = e.codec().compress(&f.data, f.dims, &cfg(parity)).unwrap();
            let full = destage::decode_with_driver(&bytes, false, None, DecodeDriver::Sequential)
                .unwrap();
            let want = region_slice(&full.data, f.dims, region);
            let verify_modes: &[bool] =
                if e.codec().supports_verify() { &[false, true] } else { &[false] };
            for &v in verify_modes {
                for driver in DRIVERS {
                    let got =
                        destage::decode_with_driver(&bytes, v, Some(region), driver).unwrap();
                    assert_eq!(
                        bits(&got.data),
                        bits(&want),
                        "{} parity={parity} verify={v} {driver:?}",
                        e.name()
                    );
                }
            }
            // public region APIs at {1,2,4} workers
            for w in [1usize, 2, 4] {
                let got = e
                    .codec()
                    .decompress_region(&bytes, region, Parallelism::from_workers(w))
                    .unwrap();
                assert_eq!(bits(&got), bits(&want), "{} region w={w}", e.name());
                if e.codec().supports_region_verified() {
                    let (got, report) = e
                        .codec()
                        .decompress_region_verified(
                            &bytes,
                            region,
                            Parallelism::from_workers(w),
                        )
                        .unwrap();
                    assert_eq!(bits(&got), bits(&want), "{} vregion w={w}", e.name());
                    assert!(report.is_clean());
                }
            }
        }
    }
}

#[test]
fn streaming_decode_bit_identical_across_drivers_engines_and_formats() {
    // Chain shape 3 on the decode side: blocks committed straight into a
    // sink must carry the very same bits as the materializing decode, on
    // every driver, verified and not, v1 and v2, for all four per-block
    // engines — and the placement must cover every point exactly.
    use ftsz::compressor::stream::VecSink;
    let f = field();
    for parity in [false, true] {
        for e in [
            Engine::RandomAccess,
            Engine::FaultTolerant,
            Engine::UltraFast,
            Engine::UltraFastFT,
        ] {
            let bytes = e.codec().compress(&f.data, f.dims, &cfg(parity)).unwrap();
            let reference =
                destage::decode_with_driver(&bytes, false, None, DecodeDriver::Sequential)
                    .unwrap();
            let verify_modes: &[bool] =
                if e.codec().supports_verify() { &[false, true] } else { &[false] };
            for &v in verify_modes {
                for driver in DRIVERS {
                    let mut sink = VecSink::new(f.dims.len());
                    let out =
                        destage::decode_stream_with_driver(&bytes, &mut sink, v, driver)
                            .unwrap();
                    assert_eq!(out.dims, f.dims);
                    assert!(out.report.is_clean());
                    assert_eq!(
                        bits(&sink.into_data()),
                        bits(&reference.data),
                        "{} parity={parity} verify={v} {driver:?} streaming",
                        e.name()
                    );
                }
            }
            // the public worker-count streaming APIs agree as well
            for w in [1usize, 2, 4] {
                let mut sink = VecSink::new(f.dims.len());
                engine::decompress_stream(&bytes, &mut sink, Parallelism::from_workers(w))
                    .unwrap();
                assert_eq!(
                    bits(&sink.into_data()),
                    bits(&reference.data),
                    "{} parity={parity} w={w} streaming",
                    e.name()
                );
                if e.codec().supports_verify() {
                    let mut sink = VecSink::new(f.dims.len());
                    let out =
                        ft::decompress_stream(&bytes, &mut sink, Parallelism::from_workers(w))
                            .unwrap();
                    assert!(out.report.is_clean());
                    assert_eq!(
                        bits(&sink.into_data()),
                        bits(&reference.data),
                        "{} parity={parity} w={w} verified streaming",
                        e.name()
                    );
                }
            }
        }
        // classic streams through the documented materializing fallback
        let bytes = Engine::Classic.codec().compress(&f.data, f.dims, &cfg(parity)).unwrap();
        let want = classic::decompress(&bytes).unwrap();
        let mut sink = VecSink::new(f.dims.len());
        let out = engine::decompress_stream(&bytes, &mut sink, Parallelism::Fixed(4)).unwrap();
        assert_eq!(out.dims, f.dims);
        assert_eq!(bits(&sink.into_data()), bits(&want.data), "classic streaming fallback");
    }
}

#[test]
fn v2_repairs_are_reported_as_stripes_on_every_decode_path() {
    let f = field();
    let bytes = ft::compress(&f.data, f.dims, &cfg(true)).unwrap();
    // find a flip in the protected region that the parity layer repairs
    let mut damaged = bytes.clone();
    damaged[bytes.len() / 2] ^= 0x08;
    let (dec, report) = ft::decompress_with_report(&damaged, Parallelism::Sequential).unwrap();
    assert!(
        !report.stripes_repaired.is_empty(),
        "mid-archive flip should have needed a parity rebuild"
    );
    assert_eq!(report.blocks_reexecuted, 0, "at-rest damage is not a re-execution");
    assert!(ftsz::analysis::max_abs_err(&f.data, &dec.data) <= 1e-3);
    // the unverified ablation path surfaces the same repair now
    let (_, unv) = ft::decompress_unverified(&damaged).unwrap();
    assert_eq!(unv.stripes_repaired, report.stripes_repaired);
    // ...and so does verified region decode
    let region = Region { origin: (0, 0, 0), shape: (4, 4, 4) };
    let (_, reg) = ft::decompress_region_verified(&damaged, region, Parallelism::Sequential)
        .unwrap();
    assert_eq!(reg.stripes_repaired, report.stripes_repaired);
}

#[test]
fn verified_region_detects_flips_that_full_verified_decode_detects() {
    // an ftrsz v1 archive (no parity): a bit flip in the stored bytes is
    // persistent, so re-execution cannot heal it — full verified decode
    // reports it as an error. Verified region decode over the whole domain
    // must reach the same verdict; unprotected region decode of the same
    // bytes is exactly the silent path this PR closed.
    let f = field();
    let bytes = ft::compress(&f.data, f.dims, &cfg(false)).unwrap();
    let all = Region::all(f.dims);
    let mut detected = 0usize;
    for seed in 0..60u64 {
        let mut bad = bytes.clone();
        // deterministic pseudo-random strike derived from the seed
        let off = (seed as usize * 2654435761) % bytes.len();
        let bit = (seed % 8) as u8;
        bad[off] ^= 1 << bit;
        match ft::decompress(&bad) {
            Err(_) => {
                detected += 1;
                assert!(
                    ft::decompress_region_verified(&bad, all, Parallelism::Sequential)
                        .is_err(),
                    "seed {seed}: full verify detected the flip at byte {off} but \
                     verified region decode of the whole domain did not"
                );
            }
            Ok(dec) => {
                // harmless flip (slack/metadata that still decodes in
                // bound): verified region must then also succeed in bound
                assert!(ftsz::analysis::max_abs_err(&f.data, &dec.data) <= 1e-3);
                let (got, _) =
                    ft::decompress_region_verified(&bad, all, Parallelism::Sequential)
                        .unwrap();
                assert_eq!(bits(&got), bits(&dec.data));
            }
        }
    }
    assert!(detected > 10, "campaign too weak: only {detected}/60 flips detected");
}

#[test]
fn verified_subregion_localizes_detection_to_the_damaged_block() {
    // strike one block's payload; a verified region that contains the
    // block must error, a verified region disjoint from it must succeed
    let f = field();
    let b = 6usize; // cfg() block size
    let bytes = ft::compress(&f.data, f.dims, &cfg(false)).unwrap();
    let clean = engine::decompress(&bytes).unwrap();
    let (dz, ry, rx) = f.dims.as_3d();
    let mut exercised = 0usize;
    for seed in 0..200u64 {
        let mut bad = bytes.clone();
        let off = (seed as usize * 40503) % bytes.len();
        bad[off] ^= 1 << (seed % 8);
        // interesting case: full verified decode detects, but the bytes
        // still parse and decode unverified — the silent-SDC shape
        if ft::decompress(&bad).is_ok() {
            continue;
        }
        let Ok(dirty) = engine::decompress(&bad) else { continue };
        // locate the damaged points; skip if more than one block is hit
        let mut hit_block: Option<(usize, usize, usize)> = None;
        let mut multi = false;
        for (i, (a, d)) in clean.data.iter().zip(&dirty.data).enumerate() {
            if a.to_bits() != d.to_bits() {
                let z = i / (ry * rx);
                let y = (i / rx) % ry;
                let x = i % rx;
                let blk = (z / b, y / b, x / b);
                match hit_block {
                    None => hit_block = Some(blk),
                    Some(h) if h != blk => multi = true,
                    Some(_) => {}
                }
            }
        }
        let Some((bz, by, bx)) = hit_block else { continue };
        if multi {
            continue;
        }
        exercised += 1;
        // region = exactly the damaged block
        let damaged_region = Region {
            origin: (bz * b, by * b, bx * b),
            shape: (
                b.min(dz - bz * b),
                b.min(ry - by * b),
                b.min(rx - bx * b),
            ),
        };
        assert!(
            ft::decompress_region_verified(&bad, damaged_region, Parallelism::Sequential)
                .is_err(),
            "seed {seed}: verified region over the damaged block must detect"
        );
        // region = a block far away (opposite corner), must verify clean
        let far = Region {
            origin: (0, 0, 0),
            shape: (b.min(dz), b.min(ry), b.min(rx)),
        };
        if far.origin != damaged_region.origin {
            let (got, report) =
                ft::decompress_region_verified(&bad, far, Parallelism::Sequential).unwrap();
            assert!(report.is_clean());
            assert_eq!(
                bits(&got),
                bits(&region_slice(&clean.data, f.dims, far)),
                "seed {seed}: clean far-away block decoded differently"
            );
        }
        if exercised >= 5 {
            break;
        }
    }
    assert!(exercised > 0, "no strike produced the single-damaged-block shape");
}

#[test]
fn region_decode_reports_parity_repairs_on_unverified_engines() {
    // PR 4 closed the report gap for *full* unverified decodes; the
    // region path kept it. A damaged v2 archive decoded through
    // `engine::decompress_region_reported` must surface the stripe
    // rebuild for the engines with no verify path at all (rsz, xsz) —
    // otherwise at-rest healing is invisible exactly where random access
    // makes it most likely to go unnoticed.
    let f = field();
    let region = Region { origin: (2, 3, 1), shape: (4, 6, 8) };
    for e in [Engine::RandomAccess, Engine::UltraFast] {
        let bytes = e.codec().compress(&f.data, f.dims, &cfg(true)).unwrap();
        let want = {
            let full =
                destage::decode_with_driver(&bytes, false, None, DecodeDriver::Sequential)
                    .unwrap();
            region_slice(&full.data, f.dims, region)
        };
        let mut damaged = bytes.clone();
        damaged[bytes.len() / 2] ^= 0x08;
        for w in [1usize, 4] {
            let (got, report) = engine::decompress_region_reported(
                &damaged,
                region,
                Parallelism::from_workers(w),
            )
            .unwrap();
            assert!(
                !report.stripes_repaired.is_empty(),
                "{} w={w}: region decode hid the parity rebuild",
                e.name()
            );
            assert_eq!(report.blocks_reexecuted, 0, "{}: at-rest repair domain", e.name());
            assert_eq!(bits(&got), bits(&want), "{} w={w}: healed region differs", e.name());
        }
        // the same damage through the plain (report-less) region API must
        // still heal — the report variant only adds visibility
        let got = engine::decompress_region(&damaged, region).unwrap();
        assert_eq!(bits(&got), bits(&want), "{}: plain region decode", e.name());
    }
}

#[test]
fn scrub_heals_an_xsz_v2_archive_in_place() {
    // the maintenance path (PR 3's scrub API) applies to the fourth
    // engine's archives unchanged: damage inside the protected region is
    // localized, rebuilt, and the healed bytes decode identically
    use ftsz::compressor::xsz;
    use ftsz::ft::ScrubOutcome;
    let f = field();
    let clean = xsz::compress_ft(&f.data, f.dims, &cfg(true)).unwrap();
    let reference = ft::decompress(&clean).unwrap();
    // clean archives scrub clean
    let (outcome, healed) = ft::parity::scrub(&clean).unwrap();
    assert!(matches!(outcome, ScrubOutcome::Clean));
    assert!(healed.is_none());
    // damaged archives are repaired and the healed bytes round-trip
    let mut damaged = clean.clone();
    damaged[clean.len() / 3] ^= 0x40;
    let (outcome, healed) = ft::parity::scrub(&damaged).unwrap();
    let ScrubOutcome::Repaired(report) = outcome else {
        panic!("damaged xsz archive scrubbed as {outcome:?}");
    };
    assert!(!report.stripes_repaired.is_empty());
    let healed = healed.expect("repair returns the healed bytes");
    assert_eq!(healed, clean, "scrub must restore the original bytes exactly");
    let dec = ft::decompress(&healed).unwrap();
    assert_eq!(bits(&dec.data), bits(&reference.data));
    // v1 (unprotected) xsz archives report Unprotected, not an error
    let v1 = xsz::compress_ft(&f.data, f.dims, &cfg(false)).unwrap();
    let (outcome, _) = ft::parity::scrub(&v1).unwrap();
    assert!(matches!(outcome, ScrubOutcome::Unprotected));
}
