//! Property-based tests over the whole stack (in-repo `util::prop`
//! framework; proptest is unavailable offline). Each property runs many
//! seeded random cases; failures print a reproduction seed.

use ftsz::analysis;
use ftsz::compressor::block::{BlockGrid, Region};
use ftsz::compressor::huffman::HuffmanTable;
use ftsz::compressor::{
    classic, dualquant, engine, xsz, CompressionConfig, ErrorBound, Parallelism,
};
use ftsz::data::Dims;
use ftsz::ft::checksum::{self, Correction};
use ftsz::util::bits::{BitReader, BitWriter};
use ftsz::util::prop::forall;

#[test]
fn prop_roundtrip_error_bound() {
    forall("engine roundtrip respects bound", 40, |g| {
        let dz = g.usize_in(1, 8);
        let dy = g.usize_in(1, 12);
        let dx = g.usize_in(1, 12);
        let dims = Dims::d3(dz, dy, dx);
        let mut data = Vec::with_capacity(dims.len());
        let mut v = g.f64_in(-10.0, 10.0);
        for _ in 0..dims.len() {
            v += g.f64_in(-0.5, 0.5);
            data.push(v as f32);
        }
        let e = 10f64.powi(-(g.usize_in(1, 5) as i32));
        let b = g.usize_in(2, 12);
        let cfg = CompressionConfig::new(ErrorBound::Abs(e)).with_block_size(b);
        let bytes = engine::compress(&data, dims, &cfg).map_err(|x| x.to_string())?;
        let dec = engine::decompress(&bytes).map_err(|x| x.to_string())?;
        let max = analysis::max_abs_err(&data, &dec.data);
        if max <= e {
            Ok(())
        } else {
            Err(format!("dims {dims:?} b {b} e {e}: max {max}"))
        }
    });
}

#[test]
fn prop_ft_roundtrip_bitwise_equals_plain() {
    forall("ft and plain decompressions agree bitwise", 25, |g| {
        let n = g.usize_in(8, 600);
        let data = g.vec_f32_smooth(n.max(8));
        let dims = Dims::d1(data.len());
        let cfg = CompressionConfig::new(ErrorBound::Abs(1e-3)).with_block_size(g.usize_in(2, 10));
        let a = engine::compress(&data, dims, &cfg).map_err(|x| x.to_string())?;
        let b = ftsz::ft::compress(&data, dims, &cfg).map_err(|x| x.to_string())?;
        let da = engine::decompress(&a).map_err(|x| x.to_string())?;
        let db = ftsz::ft::decompress(&b).map_err(|x| x.to_string())?;
        if da.data.iter().zip(&db.data).all(|(x, y)| x.to_bits() == y.to_bits()) {
            Ok(())
        } else {
            Err("ft changed numerics".into())
        }
    });
}

#[test]
fn prop_checksum_locates_any_single_flip() {
    forall("checksum locates any single flip", 120, |g| {
        let data = g.vec_f32(2000);
        let c0 = checksum::checksum_f32(&data);
        let j = g.usize_in(0, data.len() - 1);
        let bit = g.usize_in(0, 31);
        let mut bad = data.clone();
        bad[j] = f32::from_bits(bad[j].to_bits() ^ (1 << bit));
        match checksum::verify_correct_f32(&mut bad, c0) {
            Correction::Corrected { index } if index == j => {
                if bad[j].to_bits() == data[j].to_bits() {
                    Ok(())
                } else {
                    Err("repair produced wrong bits".into())
                }
            }
            Correction::Clean => {
                // flipping a bit twice in the same spot can't happen here;
                // Clean means the flip was a no-op (impossible) — fail
                Err("flip went undetected".into())
            }
            other => Err(format!("unexpected {other:?} for j={j} bit={bit}")),
        }
    });
}

#[test]
fn prop_huffman_roundtrip_arbitrary_histograms() {
    forall("huffman roundtrip", 60, |g| {
        let n_sym = g.usize_in(1, 512);
        let freqs: Vec<u64> = (0..n_sym).map(|_| g.u64() % 1000).collect();
        if freqs.iter().all(|&f| f == 0) {
            return Ok(());
        }
        let table = HuffmanTable::from_frequencies(&freqs).map_err(|e| e.to_string())?;
        let live: Vec<u32> =
            freqs.iter().enumerate().filter(|(_, &f)| f > 0).map(|(s, _)| s as u32).collect();
        let stream: Vec<u32> =
            (0..g.usize_in(1, 400)).map(|_| live[g.usize_in(0, live.len() - 1)]).collect();
        let mut w = BitWriter::new();
        for &s in &stream {
            table.encode(&mut w, s).map_err(|e| e.to_string())?;
        }
        let bits = w.bit_len();
        let buf = w.finish();
        let mut r = BitReader::with_limit(&buf, bits).map_err(|e| e.to_string())?;
        for &s in &stream {
            let got = table.decode(&mut r).map_err(|e| e.to_string())?;
            if got != s {
                return Err(format!("decoded {got}, wanted {s}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dualquant_inverse_is_exact() {
    forall("dualquant inverse reproduces forward dcmp bitwise", 60, |g| {
        let nz = g.usize_in(1, 8);
        let ny = g.usize_in(1, 8);
        let nx = g.usize_in(1, 8);
        let n = nz * ny * nx;
        let block: Vec<f32> = (0..n).map(|_| g.f64_in(-100.0, 100.0) as f32).collect();
        let e = 10f64.powi(-(g.usize_in(1, 4) as i32));
        let (mut bins, mut dcmp, mut back) = (Vec::new(), Vec::new(), Vec::new());
        dualquant::forward(&block, (nz, ny, nx), e, &mut bins, &mut dcmp);
        dualquant::inverse(&bins, (nz, ny, nx), e, &mut back);
        if back.iter().zip(&dcmp).all(|(a, b)| a.to_bits() == b.to_bits()) {
            Ok(())
        } else {
            Err(format!("shape ({nz},{ny},{nx}) e {e}"))
        }
    });
}

#[test]
fn prop_blockgrid_partition() {
    forall("blocks partition the domain", 80, |g| {
        let dims = Dims::d3(g.usize_in(1, 20), g.usize_in(1, 20), g.usize_in(1, 20));
        let b = g.usize_in(1, 12);
        let grid = BlockGrid::new(dims, b).map_err(|e| e.to_string())?;
        let mut covered = vec![0u8; dims.len()];
        let data: Vec<f32> = (0..dims.len()).map(|i| i as f32).collect();
        let mut block = Vec::new();
        let mut total = 0usize;
        for i in 0..grid.n_blocks() {
            let e = grid.extent(i);
            total += e.len();
            grid.extract(&data, i, &mut block);
            // mark coverage through scatter of a sentinel
            let ones = vec![1.0f32; e.len()];
            let mut cover_f: Vec<f32> = covered.iter().map(|&v| v as f32).collect();
            grid.scatter(&ones, i, &mut cover_f);
            for (c, v) in covered.iter_mut().zip(cover_f) {
                *c = v as u8;
            }
        }
        if total != dims.len() {
            return Err(format!("extents sum {total} != {}", dims.len()));
        }
        if covered.iter().all(|&c| c == 1) {
            Ok(())
        } else {
            Err("not all points covered".into())
        }
    });
}

#[test]
fn prop_region_decode_equals_full_slice() {
    forall("region decode equals full-decode slice", 25, |g| {
        let dims = Dims::d3(g.usize_in(2, 10), g.usize_in(2, 14), g.usize_in(2, 14));
        let mut data = Vec::with_capacity(dims.len());
        let mut v = 0.0f64;
        for _ in 0..dims.len() {
            v += g.f64_in(-0.1, 0.1);
            data.push(v as f32);
        }
        let cfg = CompressionConfig::new(ErrorBound::Abs(1e-3)).with_block_size(g.usize_in(2, 6));
        let bytes = engine::compress(&data, dims, &cfg).map_err(|e| e.to_string())?;
        let full = engine::decompress(&bytes).map_err(|e| e.to_string())?;
        let (d, r, c) = dims.as_3d();
        let oz = g.usize_in(0, d - 1);
        let oy = g.usize_in(0, r - 1);
        let ox = g.usize_in(0, c - 1);
        let region = Region {
            origin: (oz, oy, ox),
            shape: (g.usize_in(1, d - oz), g.usize_in(1, r - oy), g.usize_in(1, c - ox)),
        };
        let got = engine::decompress_region(&bytes, region).map_err(|e| e.to_string())?;
        let mut idx = 0;
        for z in 0..region.shape.0 {
            for y in 0..region.shape.1 {
                for x in 0..region.shape.2 {
                    let gidx = ((oz + z) * r + oy + y) * c + ox + x;
                    if got[idx].to_bits() != full.data[gidx].to_bits() {
                        return Err(format!("mismatch at {z},{y},{x}"));
                    }
                    idx += 1;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_and_sequential_byte_identical_all_engines() {
    // The tentpole invariant: the Parallelism knob reorders computation,
    // never the format. For every engine, random shape, block size and
    // 1–8 workers, the archive bytes and the decompressed bits must be
    // identical to the sequential reference.
    forall("parallel == sequential (bytes and bits)", 20, |g| {
        let dims = Dims::d3(g.usize_in(2, 8), g.usize_in(2, 12), g.usize_in(2, 12));
        let mut data = Vec::with_capacity(dims.len());
        let mut v = g.f64_in(-5.0, 5.0);
        for _ in 0..dims.len() {
            v += g.f64_in(-0.3, 0.3);
            data.push(v as f32);
        }
        let b = g.usize_in(2, 12);
        let workers = g.usize_in(1, 8);
        let seq_cfg = CompressionConfig::new(ErrorBound::Abs(1e-3)).with_block_size(b);
        let par_cfg = seq_cfg.clone().with_workers(workers);

        // rsz: archives byte-identical
        let a_seq = engine::compress(&data, dims, &seq_cfg).map_err(|e| e.to_string())?;
        let a_par = engine::compress(&data, dims, &par_cfg).map_err(|e| e.to_string())?;
        if a_seq != a_par {
            return Err(format!("rsz archive differs at {workers} workers (b={b})"));
        }
        // ftrsz: archives byte-identical (checksums are block-local)
        let f_seq = ftsz::ft::compress(&data, dims, &seq_cfg).map_err(|e| e.to_string())?;
        let f_par = ftsz::ft::compress(&data, dims, &par_cfg).map_err(|e| e.to_string())?;
        if f_seq != f_par {
            return Err(format!("ftrsz archive differs at {workers} workers (b={b})"));
        }
        // xsz / ftxsz: the SZx-style chain has its own drivers — same law
        let x_seq = xsz::compress(&data, dims, &seq_cfg).map_err(|e| e.to_string())?;
        let x_par = xsz::compress(&data, dims, &par_cfg).map_err(|e| e.to_string())?;
        if x_seq != x_par {
            return Err(format!("xsz archive differs at {workers} workers (b={b})"));
        }
        let fx_seq = xsz::compress_ft(&data, dims, &seq_cfg).map_err(|e| e.to_string())?;
        let fx_par = xsz::compress_ft(&data, dims, &par_cfg).map_err(|e| e.to_string())?;
        if fx_seq != fx_par {
            return Err(format!("ftxsz archive differs at {workers} workers (b={b})"));
        }
        // classic: the knob is documented-ignored; bytes must not change
        let c_seq = classic::compress(&data, dims, &seq_cfg).map_err(|e| e.to_string())?;
        let c_par = classic::compress(&data, dims, &par_cfg).map_err(|e| e.to_string())?;
        if c_seq != c_par {
            return Err("classic archive changed under the parallelism knob".into());
        }

        // decompressions bitwise identical (plain + verified)
        let par = Parallelism::Fixed(workers);
        let d_seq = engine::decompress(&a_seq).map_err(|e| e.to_string())?;
        let d_par = engine::decompress_with(&a_seq, par).map_err(|e| e.to_string())?;
        if !d_seq.data.iter().zip(&d_par.data).all(|(x, y)| x.to_bits() == y.to_bits()) {
            return Err(format!("rsz decode differs at {workers} workers"));
        }
        let v_seq = ftsz::ft::decompress(&f_seq).map_err(|e| e.to_string())?;
        let v_par = ftsz::ft::decompress_with(&f_seq, par).map_err(|e| e.to_string())?;
        if !v_seq.data.iter().zip(&v_par.data).all(|(x, y)| x.to_bits() == y.to_bits()) {
            return Err(format!("ftrsz verified decode differs at {workers} workers"));
        }
        let vx_seq = ftsz::ft::decompress(&fx_seq).map_err(|e| e.to_string())?;
        let vx_par = ftsz::ft::decompress_with(&fx_seq, par).map_err(|e| e.to_string())?;
        if !vx_seq.data.iter().zip(&vx_par.data).all(|(x, y)| x.to_bits() == y.to_bits()) {
            return Err(format!("ftxsz verified decode differs at {workers} workers"));
        }

        // random-access region decode bitwise identical
        let (d, r, c) = dims.as_3d();
        let oz = g.usize_in(0, d - 1);
        let oy = g.usize_in(0, r - 1);
        let ox = g.usize_in(0, c - 1);
        let region = Region {
            origin: (oz, oy, ox),
            shape: (g.usize_in(1, d - oz), g.usize_in(1, r - oy), g.usize_in(1, c - ox)),
        };
        let r_seq = engine::decompress_region(&a_seq, region).map_err(|e| e.to_string())?;
        let r_par = engine::decompress_region_with(&a_seq, region, par)
            .map_err(|e| e.to_string())?;
        if !r_seq.iter().zip(&r_par).all(|(x, y)| x.to_bits() == y.to_bits()) {
            return Err(format!("region decode differs at {workers} workers"));
        }
        Ok(())
    });
}

#[test]
fn prop_unified_codec_dispatch_all_engines() {
    // The stage-graph contract: every engine behind the one BlockCodec
    // dispatch, exercised end to end — compress, natural decompress,
    // verified decompress, and region decode — for random shapes,
    // {1, 2, 4} workers, and parity (format v2) on/off. Bytes and bits
    // must be independent of the worker count; unsupported paths must be
    // clean errors, never panics or silent misdecodes.
    use ftsz::ft::parity::ParityParams;
    use ftsz::inject::Engine;
    forall("unified BlockCodec dispatch", 12, |g| {
        let dims = Dims::d3(g.usize_in(2, 6), g.usize_in(2, 10), g.usize_in(2, 10));
        let mut data = Vec::with_capacity(dims.len());
        let mut v = g.f64_in(-5.0, 5.0);
        for _ in 0..dims.len() {
            v += g.f64_in(-0.3, 0.3);
            data.push(v as f32);
        }
        let mut cfg =
            CompressionConfig::new(ErrorBound::Abs(1e-3)).with_block_size(g.usize_in(2, 8));
        let parity = g.usize_in(0, 1) == 1;
        if parity {
            cfg = cfg.with_archive_parity(ParityParams::xor(64, 8));
        }
        let (d, r, c) = dims.as_3d();
        let oz = g.usize_in(0, d - 1);
        let oy = g.usize_in(0, r - 1);
        let ox = g.usize_in(0, c - 1);
        let region = Region {
            origin: (oz, oy, ox),
            shape: (g.usize_in(1, d - oz), g.usize_in(1, r - oy), g.usize_in(1, c - ox)),
        };
        for e in Engine::ALL {
            let codec = e.codec();
            let base = codec
                .compress(&data, dims, &cfg)
                .map_err(|x| format!("{} compress: {x}", e.name()))?;
            let full = codec
                .decompress(&base, Parallelism::Sequential)
                .map_err(|x| format!("{} decompress: {x}", e.name()))?;
            if analysis::max_abs_err(&data, &full.data) > 1e-3 {
                return Err(format!("{} bound violated (parity={parity})", e.name()));
            }
            for w in [1usize, 2, 4] {
                let par = Parallelism::Fixed(w);
                // compression bytes independent of the worker count
                let b = codec
                    .compress(&data, dims, &cfg.clone().with_workers(w))
                    .map_err(|x| x.to_string())?;
                if b != base {
                    return Err(format!("{} bytes differ at {w} workers", e.name()));
                }
                // natural decode bitwise stable across worker counts
                let dw = codec.decompress(&base, par).map_err(|x| x.to_string())?;
                if !dw.data.iter().zip(&full.data).all(|(a, b)| a.to_bits() == b.to_bits()) {
                    return Err(format!("{} decode differs at {w} workers", e.name()));
                }
                // verified decompression: supported ⇔ ftrsz, clean either way
                match codec.decompress_verified(&base, par) {
                    Ok((dv, report)) => {
                        if !codec.supports_verify() {
                            return Err(format!("{} verified but unsupported", e.name()));
                        }
                        if !report.is_clean() {
                            return Err(format!("{} clean run reported events", e.name()));
                        }
                        if !dv.data.iter().zip(&full.data).all(|(a, b)| a.to_bits() == b.to_bits())
                        {
                            return Err(format!("{} verify changed bits (w={w})", e.name()));
                        }
                    }
                    Err(_) if !codec.supports_verify() => {}
                    Err(x) => return Err(format!("{} verify failed: {x}", e.name())),
                }
                // region decode: supported ⇔ rsz/ftrsz, matches the full
                // decode slice bitwise
                match codec.decompress_region(&base, region, par) {
                    Ok(got) => {
                        if !codec.supports_region() {
                            return Err(format!("{} region but unsupported", e.name()));
                        }
                        let mut idx = 0;
                        for z in 0..region.shape.0 {
                            for y in 0..region.shape.1 {
                                for x in 0..region.shape.2 {
                                    let gi = ((oz + z) * r + oy + y) * c + ox + x;
                                    if got[idx].to_bits() != full.data[gi].to_bits() {
                                        return Err(format!(
                                            "{} region mismatch at {z},{y},{x} (w={w})",
                                            e.name()
                                        ));
                                    }
                                    idx += 1;
                                }
                            }
                        }
                    }
                    Err(_) if !codec.supports_region() => {}
                    Err(x) => return Err(format!("{} region failed: {x}", e.name())),
                }
                // verified region decode: supported ⇔ ftrsz, bits match the
                // full decode slice, clean report on clean archives
                match codec.decompress_region_verified(&base, region, par) {
                    Ok((got, report)) => {
                        if !codec.supports_region_verified() {
                            return Err(format!("{} vregion but unsupported", e.name()));
                        }
                        if !report.is_clean() {
                            return Err(format!("{} clean vregion reported events", e.name()));
                        }
                        let mut idx = 0;
                        for z in 0..region.shape.0 {
                            for y in 0..region.shape.1 {
                                for x in 0..region.shape.2 {
                                    let gi = ((oz + z) * r + oy + y) * c + ox + x;
                                    if got[idx].to_bits() != full.data[gi].to_bits() {
                                        return Err(format!(
                                            "{} vregion mismatch at {z},{y},{x} (w={w})",
                                            e.name()
                                        ));
                                    }
                                    idx += 1;
                                }
                            }
                        }
                    }
                    Err(_) if !codec.supports_region_verified() => {}
                    Err(x) => return Err(format!("{} vregion failed: {x}", e.name())),
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_streaming_equals_in_memory_all_engines() {
    // Chain shape 3: the slab-streaming compress path must emit the very
    // same bytes as the in-memory path — every engine (classic goes
    // through the documented materializing fallback), {1, 2, 4} workers,
    // v1 and parity-v2 containers — and the streaming decode must place
    // the very same bits the materializing decode returns.
    use ftsz::compressor::stream::{SliceSource, VecSink};
    use ftsz::ft::parity::ParityParams;
    use ftsz::inject::Engine;
    forall("streaming == in-memory (bytes and bits)", 10, |g| {
        let dims = Dims::d3(g.usize_in(2, 6), g.usize_in(2, 10), g.usize_in(2, 10));
        let mut data = Vec::with_capacity(dims.len());
        let mut v = g.f64_in(-5.0, 5.0);
        for _ in 0..dims.len() {
            v += g.f64_in(-0.3, 0.3);
            data.push(v as f32);
        }
        let mut cfg =
            CompressionConfig::new(ErrorBound::Abs(1e-3)).with_block_size(g.usize_in(2, 8));
        if g.usize_in(0, 1) == 1 {
            cfg = cfg.with_archive_parity(ParityParams::xor(64, 8));
        }
        for e in Engine::ALL {
            let codec = e.codec();
            for w in [1usize, 2, 4] {
                let wcfg = cfg.clone().with_workers(w);
                let mem = codec.compress(&data, dims, &wcfg).map_err(|x| x.to_string())?;
                let mut src = SliceSource::new(dims, &data).map_err(|x| x.to_string())?;
                let strm =
                    codec.compress_stream(&mut src, &wcfg).map_err(|x| x.to_string())?;
                if mem != strm {
                    return Err(format!("{} streaming bytes differ at {w} workers", e.name()));
                }
                // streaming decode places the same bits the materializing
                // decode returns
                let full = codec
                    .decompress(&mem, Parallelism::Fixed(w))
                    .map_err(|x| x.to_string())?;
                let mut sink = VecSink::new(dims.len());
                let out = engine::decompress_stream(&mem, &mut sink, Parallelism::Fixed(w))
                    .map_err(|x| format!("{} stream decode: {x}", e.name()))?;
                if out.dims != dims {
                    return Err(format!("{} stream decode dims {:?}", e.name(), out.dims));
                }
                let placed = sink.into_data();
                if !placed.iter().zip(&full.data).all(|(a, b)| a.to_bits() == b.to_bits()) {
                    return Err(format!(
                        "{} streaming decode differs at {w} workers",
                        e.name()
                    ));
                }
                // ft archives also stream through the Algorithm 2 chain
                if codec.supports_verify() {
                    let mut vsink = VecSink::new(dims.len());
                    let vout =
                        ftsz::ft::decompress_stream(&mem, &mut vsink, Parallelism::Fixed(w))
                            .map_err(|x| x.to_string())?;
                    if !vout.report.is_clean() {
                        return Err(format!(
                            "{} clean stream-verify reported events",
                            e.name()
                        ));
                    }
                    let vplaced = vsink.into_data();
                    if !vplaced.iter().zip(&full.data).all(|(a, b)| a.to_bits() == b.to_bits())
                    {
                        return Err(format!("{} verified streaming decode differs", e.name()));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_decode_drivers_bit_identical() {
    // the decode-graph tentpole invariant: sequential / pipelined /
    // block-parallel drivers are bit-interchangeable for full, verified
    // and region decode at random shapes and block sizes
    use ftsz::compressor::destage::{decode_with_driver, DecodeDriver};
    forall("decode drivers bit-identical", 15, |g| {
        let dims = Dims::d3(g.usize_in(2, 8), g.usize_in(2, 12), g.usize_in(2, 12));
        let mut data = Vec::with_capacity(dims.len());
        let mut v = g.f64_in(-5.0, 5.0);
        for _ in 0..dims.len() {
            v += g.f64_in(-0.3, 0.3);
            data.push(v as f32);
        }
        let cfg = CompressionConfig::new(ErrorBound::Abs(1e-3)).with_block_size(g.usize_in(2, 8));
        let bytes = ftsz::ft::compress(&data, dims, &cfg).map_err(|e| e.to_string())?;
        let (d, r, c) = dims.as_3d();
        let oz = g.usize_in(0, d - 1);
        let oy = g.usize_in(0, r - 1);
        let ox = g.usize_in(0, c - 1);
        let region = Region {
            origin: (oz, oy, ox),
            shape: (g.usize_in(1, d - oz), g.usize_in(1, r - oy), g.usize_in(1, c - ox)),
        };
        let verify = g.usize_in(0, 1) == 1;
        let reg = g.usize_in(0, 1) == 1;
        let region_arg = if reg { Some(region) } else { None };
        let base = decode_with_driver(&bytes, verify, region_arg, DecodeDriver::Sequential)
            .map_err(|e| e.to_string())?;
        for driver in
            [DecodeDriver::Pipelined, DecodeDriver::Parallel(2), DecodeDriver::Parallel(5)]
        {
            let got = decode_with_driver(&bytes, verify, region_arg, driver)
                .map_err(|e| e.to_string())?;
            if got.data.len() != base.data.len() {
                return Err(format!(
                    "decode length differs ({driver:?}): {} vs {}",
                    got.data.len(),
                    base.data.len()
                ));
            }
            if !got.data.iter().zip(&base.data).all(|(a, b)| a.to_bits() == b.to_bits()) {
                return Err(format!(
                    "decode differs ({driver:?}, verify={verify}, region={reg})"
                ));
            }
            if !got.report.is_clean() {
                return Err(format!("clean archive reported repairs ({driver:?})"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_stage_overlap_never_changes_bytes() {
    // the pipelined 1-worker driver vs the plain sequential driver: same
    // bytes for every engine/shape/block size (rsz + ftrsz take the
    // pipeline; classic must simply ignore the knob)
    forall("stage overlap off == on (bytes)", 15, |g| {
        // span the MIN_OVERLAP_POINTS gate: small cases take the plain
        // driver on both sides, large ones genuinely exercise the pipeline
        let n = g.usize_in(512, 8000);
        let data = g.vec_f32_smooth(n);
        let dims = Dims::d1(data.len());
        let on = CompressionConfig::new(ErrorBound::Abs(1e-3)).with_block_size(g.usize_in(2, 10));
        let off = on.clone().with_stage_overlap(false);
        let a = engine::compress(&data, dims, &on).map_err(|e| e.to_string())?;
        let b = engine::compress(&data, dims, &off).map_err(|e| e.to_string())?;
        if a != b {
            return Err("rsz pipelined bytes differ".into());
        }
        let a = ftsz::ft::compress(&data, dims, &on).map_err(|e| e.to_string())?;
        let b = ftsz::ft::compress(&data, dims, &off).map_err(|e| e.to_string())?;
        if a != b {
            return Err("ftrsz pipelined bytes differ".into());
        }
        // the xsz pipeline has no Huffman barrier — still byte-stable
        let a = xsz::compress(&data, dims, &on).map_err(|e| e.to_string())?;
        let b = xsz::compress(&data, dims, &off).map_err(|e| e.to_string())?;
        if a != b {
            return Err("xsz pipelined bytes differ".into());
        }
        let a = xsz::compress_ft(&data, dims, &on).map_err(|e| e.to_string())?;
        let b = xsz::compress_ft(&data, dims, &off).map_err(|e| e.to_string())?;
        if a != b {
            return Err("ftxsz pipelined bytes differ".into());
        }
        let a = classic::compress(&data, dims, &on).map_err(|e| e.to_string())?;
        let b = classic::compress(&data, dims, &off).map_err(|e| e.to_string())?;
        if a != b {
            return Err("classic changed under the stage-overlap knob".into());
        }
        Ok(())
    });
}

#[test]
fn prop_corrupted_archives_never_panic() {
    // robustness: arbitrary single-byte corruption of a valid archive must
    // produce Ok or a clean Err — never a panic (catch via prop harness)
    forall("archive corruption is panic-free", 60, |g| {
        let data = g.vec_f32_smooth(400);
        let dims = Dims::d1(data.len());
        let cfg = CompressionConfig::new(ErrorBound::Abs(1e-2)).with_block_size(8);
        let mut bytes = ftsz::ft::compress(&data, dims, &cfg).map_err(|e| e.to_string())?;
        let pos = g.usize_in(0, bytes.len() - 1);
        let bit = g.usize_in(0, 7);
        bytes[pos] ^= 1 << bit;
        // any outcome is fine except a panic (the harness catches those)
        let _ = ftsz::ft::decompress(&bytes);
        let _ = engine::decompress(&bytes);
        // same law for the xsz container (self-describing payload tags)
        let mut xbytes = xsz::compress_ft(&data, dims, &cfg).map_err(|e| e.to_string())?;
        let xpos = g.usize_in(0, xbytes.len() - 1);
        xbytes[xpos] ^= 1 << bit;
        let _ = ftsz::ft::decompress(&xbytes);
        let _ = engine::decompress(&xbytes);
        Ok(())
    });
}

#[test]
fn prop_truncated_archives_never_panic() {
    // the truncation sweep: cut a valid archive at EVERY byte boundary
    // (which necessarily includes every section boundary — header copies,
    // meta, unpred, payload, ft, parity) and decode the prefix. Every cut
    // must come back as a clean Err, never a panic and never an Ok that
    // silently drops data.
    forall("archive truncation is panic-free", 6, |g| {
        let data = g.vec_f32_smooth(300);
        let dims = Dims::d1(data.len());
        let cfg = CompressionConfig::new(ErrorBound::Abs(1e-2)).with_block_size(8);
        for bytes in [
            ftsz::ft::compress(&data, dims, &cfg).map_err(|e| e.to_string())?,
            engine::compress(&data, dims, &cfg).map_err(|e| e.to_string())?,
            xsz::compress_ft(&data, dims, &cfg).map_err(|e| e.to_string())?,
            // bit-granular packing (tag-6 blocks): the width byte and the
            // ceil(n·w/8) body introduce new cut points the sweep must cover
            xsz::compress_ft(&data, dims, &cfg.clone().with_xsz_bitpack(true))
                .map_err(|e| e.to_string())?,
        ] {
            for len in 0..bytes.len() {
                if ftsz::ft::decompress(&bytes[..len]).is_ok() {
                    return Err(format!("ft decode of {len}/{} byte prefix was Ok", bytes.len()));
                }
                if engine::decompress(&bytes[..len]).is_ok() {
                    return Err(format!(
                        "engine decode of {len}/{} byte prefix was Ok",
                        bytes.len()
                    ));
                }
            }
        }
        Ok(())
    });
}
