//! Parity: the AOT Pallas/JAX artifacts executed through PJRT must agree
//! bit-for-bit with the native Rust twins (dual-quant Lorenzo transform and
//! ABFT checksums), and approximately with the regression fit.
//!
//! These tests need `make artifacts` to have run; they skip (with a
//! message) when the artifacts directory is absent so `cargo test` works in
//! a fresh checkout.

use ftsz::compressor::dualquant;
use ftsz::ft::checksum;
use ftsz::runtime::{default_artifacts_dir, BlockKernels, XlaRuntime};
use ftsz::util::rng::Pcg32;

const N: usize = 4;
const B: usize = 4;

fn runtime() -> Option<XlaRuntime> {
    if !cfg!(feature = "pjrt") {
        eprintln!("SKIP: built without the `pjrt` feature — rebuild with --features pjrt");
        return None;
    }
    let dir = default_artifacts_dir();
    if !dir.join("manifest.txt").is_file() {
        eprintln!("SKIP: artifacts missing at {} — run `make artifacts`", dir.display());
        return None;
    }
    Some(XlaRuntime::cpu(dir).expect("cpu runtime"))
}

fn batch(seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    let mut v = 0.0f64;
    (0..N * B * B * B)
        .map(|_| {
            v += rng.range_f64(-0.02, 0.02);
            v as f32
        })
        .collect()
}

#[test]
fn lorenzo_bins_and_dcmp_bit_exact() {
    let Some(rt) = runtime() else { return };
    let k = BlockKernels::new(&rt, N, B).expect("bind variant");
    let e = 1e-3f64;
    let x = batch(1);
    let out = k.compress(&x, e).expect("xla compress");
    let blen = B * B * B;
    for blk in 0..N {
        let (mut bins, mut dcmp) = (Vec::new(), Vec::new());
        dualquant::forward(&x[blk * blen..(blk + 1) * blen], (B, B, B), e, &mut bins, &mut dcmp);
        assert_eq!(&out.bins[blk * blen..(blk + 1) * blen], &bins[..], "block {blk} bins");
        let xla_bits: Vec<u32> =
            out.dcmp[blk * blen..(blk + 1) * blen].iter().map(|v| v.to_bits()).collect();
        let native_bits: Vec<u32> = dcmp.iter().map(|v| v.to_bits()).collect();
        assert_eq!(xla_bits, native_bits, "block {blk} dcmp");
    }
}

#[test]
fn checksums_bit_exact() {
    let Some(rt) = runtime() else { return };
    let k = BlockKernels::new(&rt, N, B).expect("bind variant");
    let x = batch(2);
    let blen = B * B * B;
    let out = k.compress(&x, 1e-3).expect("xla compress");
    for blk in 0..N {
        let cs = checksum::checksum_f32(&x[blk * blen..(blk + 1) * blen]);
        assert_eq!(out.sum_in[blk], cs.sum, "block {blk} sum_in");
        assert_eq!(out.isum_in[blk], cs.isum, "block {blk} isum_in");
        let qs = checksum::checksum_i32(&out.bins[blk * blen..(blk + 1) * blen]);
        assert_eq!(out.sum_q[blk], qs.sum, "block {blk} sum_q");
        assert_eq!(out.isum_q[blk], qs.isum, "block {blk} isum_q");
        let ds = checksum::checksum_f32(&out.dcmp[blk * blen..(blk + 1) * blen]);
        assert_eq!(out.sum_dc[blk], ds.sum, "block {blk} sum_dc");
    }
    // standalone checksum graph agrees with the fused one
    let (s, i) = k.checksums_f32(&x).expect("checksum graph");
    assert_eq!(s, out.sum_in);
    assert_eq!(i, out.isum_in);
}

#[test]
fn xla_decompress_roundtrips_with_native_forward() {
    let Some(rt) = runtime() else { return };
    let k = BlockKernels::new(&rt, N, B).expect("bind variant");
    let e = 1e-2f64;
    let x = batch(3);
    let blen = B * B * B;
    // native forward → XLA inverse
    let mut all_bins = Vec::new();
    let mut all_dcmp = Vec::new();
    for blk in 0..N {
        let (mut bins, mut dcmp) = (Vec::new(), Vec::new());
        dualquant::forward(&x[blk * blen..(blk + 1) * blen], (B, B, B), e, &mut bins, &mut dcmp);
        all_bins.extend(bins);
        all_dcmp.extend(dcmp);
    }
    let (back, sums) = k.decompress(&all_bins, e).expect("xla decompress");
    let back_bits: Vec<u32> = back.iter().map(|v| v.to_bits()).collect();
    let want_bits: Vec<u32> = all_dcmp.iter().map(|v| v.to_bits()).collect();
    assert_eq!(back_bits, want_bits);
    for blk in 0..N {
        let cs = checksum::checksum_f32(&all_dcmp[blk * blen..(blk + 1) * blen]);
        assert_eq!(sums[blk], cs.sum);
    }
    // the error bound holds end to end
    for (a, b) in x.iter().zip(back.iter()) {
        assert!((*a as f64 - *b as f64).abs() <= e * 1.05);
    }
}

#[test]
fn regression_coeffs_match_native() {
    let Some(rt) = runtime() else { return };
    let k = BlockKernels::new(&rt, N, B).expect("bind variant");
    let x = batch(4);
    let blen = B * B * B;
    let coeffs = k.regression(&x).expect("regression graph");
    assert_eq!(coeffs.len(), N * 4);
    for blk in 0..N {
        let native = ftsz::compressor::regression::fit(&x[blk * blen..(blk + 1) * blen], (B, B, B));
        for j in 0..4 {
            let (a, b) = (coeffs[blk * 4 + j], native[j]);
            assert!(
                (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                "block {blk} c{j}: xla {a} vs native {b}"
            );
        }
    }
}

#[test]
fn manifest_lists_bound_variants() {
    let Some(rt) = runtime() else { return };
    let names = rt.manifest().expect("manifest");
    for needed in ["compress_n4_b4", "decompress_n4_b4", "compress_n64_b10"] {
        assert!(names.iter().any(|n| n == needed), "missing artifact {needed}");
    }
    assert_eq!(rt.platform(), "cpu");
}

#[test]
fn offload_archives_byte_identical_native_vs_xla() {
    // the strongest parity statement: the dual-quant engine produces the
    // SAME archive whether blocks run natively or through the AOT XLA
    // artifacts, because the two transforms are bit-identical.
    let Some(rt) = runtime() else { return };
    let k = BlockKernels::new(&rt, 4, 4).expect("bind variant");
    let f = ftsz::data::synthetic::hurricane_field(
        "t",
        ftsz::data::Dims::d3(8, 10, 10), // mixes full and truncated blocks
        5,
    );
    let cfg = ftsz::compressor::CompressionConfig::new(
        ftsz::compressor::ErrorBound::Rel(1e-3),
    )
    .with_block_size(4);
    let native = ftsz::compressor::offload::compress(&f.data, f.dims, &cfg, None).unwrap();
    let xla = ftsz::compressor::offload::compress(&f.data, f.dims, &cfg, Some(&k)).unwrap();
    assert_eq!(native, xla, "offload archives must be byte-identical");
    // and they decode within the bound through the standard engine
    let dec = ftsz::compressor::engine::decompress(&native).unwrap();
    let bound = cfg.error_bound.absolute(&f.data);
    let max = f
        .data
        .iter()
        .zip(&dec.data)
        .map(|(a, b)| (*a as f64 - *b as f64).abs())
        .fold(0.0f64, f64::max);
    assert!(max <= bound, "bound violated: {max} > {bound}");
}
