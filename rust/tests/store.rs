//! Serving-layer invariants: the `ArchiveStore` must be indistinguishable
//! from a fresh one-shot decode of the file's *current* bytes — cached or
//! cold, any engine, any worker count, under concurrency, and across
//! rewrites of the file underneath it.
//!
//! * **bit-identity** — cached and uncached queries return bytes
//!   bit-identical to the one-shot region APIs, for all 5 engines × v1/v2
//!   containers × {1, 2, 4} fill workers;
//! * **generation coherence** — a `scrub` rewrite (or any rewrite) drops
//!   the stale parse and every cached block of it;
//! * **never stale-silent** — a mode-C flip landing between two queries
//!   of the same block is detected exactly as a fresh decode would detect
//!   it, never answered from cache;
//! * **concurrency** — ≥ 4 threads hammering one store stay byte-identical
//!   to the sequential baselines.

use std::path::PathBuf;

use ftsz::compressor::block::Region;
use ftsz::compressor::store::{fleet, ArchiveStore, Generation, StoreConfig};
use ftsz::compressor::{classic, engine, CompressionConfig, ErrorBound, Parallelism};
use ftsz::data::{synthetic, Dims, Field};
use ftsz::ft;
use ftsz::ft::parity::{self, ParityParams};
use ftsz::inject::Engine;

const DIMS: (usize, usize, usize) = (8, 10, 10);

fn dims() -> Dims {
    Dims::d3(DIMS.0, DIMS.1, DIMS.2)
}

fn field(seed: u64) -> Field {
    synthetic::hurricane_field("t", dims(), seed)
}

fn cfg(parity_on: bool) -> CompressionConfig {
    let c = CompressionConfig::new(ErrorBound::Abs(1e-3)).with_block_size(4);
    if parity_on {
        c.with_archive_parity(ParityParams::default())
    } else {
        c
    }
}

fn temp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ftsz_store_test_{}_{tag}.ftsz", std::process::id()))
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Classic archives have no one-shot region API; the baseline is the full
/// decode sliced by hand.
fn classic_region_baseline(bytes: &[u8], region: Region) -> Vec<f32> {
    let (dec, _) = classic::decompress_reported(bytes).unwrap();
    let (_, dy, dx) = dec.dims.as_3d();
    let (oz, oy, ox) = region.origin;
    let (sz, sy, sx) = region.shape;
    let mut out = Vec::with_capacity(region.len());
    for z in oz..oz + sz {
        for y in oy..oy + sy {
            let base = (z * dy + y) * dx + ox;
            out.extend_from_slice(&dec.data[base..base + sx]);
        }
    }
    out
}

/// Find a single healable byte flip in a v2 archive: the flipped copy is
/// the *same length* as the original and `parse_recovering` reports a
/// repaired stripe.
fn healable_corruption(clean: &[u8]) -> Vec<u8> {
    for off in (clean.len() / 4..clean.len()).step_by(97) {
        let mut c = clean.to_vec();
        c[off] ^= 0x10;
        if let Ok(a) = parity::parse_recovering(&c) {
            if a.recovered.as_ref().is_some_and(|r| !r.stripes_repaired.is_empty()) {
                return c;
            }
        }
    }
    panic!("no healable flip found");
}

#[test]
fn cached_and_uncached_queries_are_bit_identical_across_engines() {
    let f = field(5);
    let region = Region { origin: (1, 2, 3), shape: (5, 4, 4) };
    let seq = Parallelism::Sequential;
    for engine_kind in Engine::ALL {
        for parity_on in [false, true] {
            let c = cfg(parity_on);
            let bytes = engine_kind.codec().compress(&f.data, f.dims, &c).unwrap();
            let path = temp_path(&format!("matrix_{}_{parity_on}", engine_kind.name()));
            std::fs::write(&path, &bytes).unwrap();
            let ft_engine =
                matches!(engine_kind, Engine::FaultTolerant | Engine::UltraFastFT);
            let verify_modes: &[bool] = if ft_engine { &[false, true] } else { &[false] };
            for &verify in verify_modes {
                let want = if engine_kind == Engine::Classic {
                    classic_region_baseline(&bytes, region)
                } else if verify {
                    ft::decompress_region_verified(&bytes, region, seq).unwrap().0
                } else {
                    engine::decompress_region_with(&bytes, region, seq).unwrap()
                };
                for workers in [1usize, 2, 4] {
                    let store = ArchiveStore::with_defaults();
                    let (cold, r_cold) =
                        store.query_with(&path, region, verify, workers).unwrap();
                    let (warm, r_warm) =
                        store.query_with(&path, region, verify, workers).unwrap();
                    let tag = format!(
                        "engine={} parity={parity_on} verify={verify} workers={workers}",
                        engine_kind.name()
                    );
                    assert_eq!(bits(&cold), bits(&want), "cold mismatch: {tag}");
                    assert_eq!(bits(&warm), bits(&want), "warm mismatch: {tag}");
                    assert!(r_cold.is_clean() && r_warm.is_clean(), "{tag}");
                    if engine_kind != Engine::Classic {
                        assert!(
                            store.stats().cache.hits > 0,
                            "warm query never hit the cache: {tag}"
                        );
                    }
                }
            }
            let _ = std::fs::remove_file(&path);
        }
    }
}

#[test]
fn verify_without_checksums_is_a_clean_error() {
    // rsz/xsz archives carry no sum_dc; classic cannot verify at all —
    // the store must reject, not panic or silently skip the verify stage
    let f = field(6);
    let region = Region { origin: (0, 0, 0), shape: (2, 2, 2) };
    for engine_kind in [Engine::Classic, Engine::RandomAccess, Engine::UltraFast] {
        let bytes = engine_kind.codec().compress(&f.data, f.dims, &cfg(false)).unwrap();
        let path = temp_path(&format!("noverify_{}", engine_kind.name()));
        std::fs::write(&path, &bytes).unwrap();
        let store = ArchiveStore::with_defaults();
        assert!(
            store.query(&path, region, true).is_err(),
            "{} must reject verify",
            engine_kind.name()
        );
        // and the unverified path still works afterwards
        store.query(&path, region, false).unwrap();
        let _ = std::fs::remove_file(&path);
    }
}

#[test]
fn verified_and_unverified_results_never_share_cache_entries() {
    let f = field(7);
    let region = Region { origin: (0, 0, 0), shape: (4, 4, 4) };
    let bytes = ft::compress(&f.data, f.dims, &cfg(true)).unwrap();
    let path = temp_path("keys");
    std::fs::write(&path, &bytes).unwrap();
    let store = ArchiveStore::with_defaults();
    store.query(&path, region, true).unwrap();
    let after_verified = store.stats().cache.misses;
    // same blocks, unverified: must MISS (distinct key space), not reuse
    store.query(&path, region, false).unwrap();
    let after_unverified = store.stats().cache.misses;
    assert!(after_unverified > after_verified, "unverified query reused verified entries");
    // both populations are now resident: repeats of either flavor hit
    store.query(&path, region, true).unwrap();
    store.query(&path, region, false).unwrap();
    assert_eq!(store.stats().cache.misses, after_unverified);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn scrub_rewrite_changes_generation_and_drops_stale_state() {
    let f = field(8);
    let region = Region { origin: (0, 0, 0), shape: (8, 10, 10) };
    let clean = ft::compress(&f.data, f.dims, &cfg(true)).unwrap();
    let corrupt = healable_corruption(&clean);
    let path = temp_path("scrub");
    std::fs::write(&path, &corrupt).unwrap();

    let store = ArchiveStore::with_defaults();
    let (d1, r1) = store.query(&path, region, true).unwrap();
    assert!(!r1.stripes_repaired.is_empty(), "open must report the at-rest damage");
    // the open-time repair record repeats on every query of this generation
    let (_, r1b) = store.query(&path, region, true).unwrap();
    assert_eq!(r1b.stripes_repaired, r1.stripes_repaired);

    let g = Generation::of(&path).unwrap();
    parity::scrub_file(&path).unwrap();
    // the content stamp alone must discriminate the heal — no mtime
    // bumping, no sleeping
    assert_ne!(Generation::of(&path).unwrap(), g, "heal must change the generation");

    let (d2, r2) = store.query(&path, region, true).unwrap();
    assert!(r2.stripes_repaired.is_empty(), "scrubbed file must open clean: {r2:?}");
    assert_eq!(bits(&d1), bits(&d2), "healed decode must match the pre-scrub decode");
    assert!(store.stats().invalidations >= 1, "stale generation was never invalidated");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn rewritten_archive_is_served_fresh_not_stale() {
    // replace the file with a *valid* different archive between queries:
    // the store must serve the new bytes bit-identically, never the cache
    let region = Region { origin: (1, 1, 1), shape: (4, 4, 4) };
    let seq = Parallelism::Sequential;
    let fa = field(21);
    let fb = field(22);
    let a = ft::compress(&fa.data, fa.dims, &cfg(true)).unwrap();
    let b = ft::compress(&fb.data, fb.dims, &cfg(true)).unwrap();
    let want_a = ft::decompress_region_verified(&a, region, seq).unwrap().0;
    let want_b = ft::decompress_region_verified(&b, region, seq).unwrap().0;
    assert_ne!(bits(&want_a), bits(&want_b), "corpus fields must differ");

    let path = temp_path("rewrite");
    std::fs::write(&path, &a).unwrap();
    let store = ArchiveStore::with_defaults();
    let (got_a, _) = store.query(&path, region, true).unwrap();
    assert_eq!(bits(&got_a), bits(&want_a));

    let g = Generation::of(&path).unwrap();
    std::fs::write(&path, &b).unwrap();
    assert_ne!(Generation::of(&path).unwrap(), g, "rewrite must change the generation");

    let (got_b, _) = store.query(&path, region, true).unwrap();
    assert_eq!(bits(&got_b), bits(&want_b), "stale cached blocks served after rewrite");
    assert!(store.stats().invalidations >= 1);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn mode_c_flip_between_queries_is_detected_never_stale() {
    // v1 ftrsz (no parity): an at-rest flip cannot be healed, only
    // detected. After the flip lands, the store must behave exactly like
    // a fresh decode of the corrupted bytes — same outcome, same report —
    // and must never answer clean from cache.
    let f = field(9);
    let region = Region { origin: (0, 0, 0), shape: (8, 10, 10) };
    let seq = Parallelism::Sequential;
    let clean = ft::compress(&f.data, f.dims, &cfg(false)).unwrap();
    let clean_vals = ft::decompress_region_verified(&clean, region, seq).unwrap().0;

    // find a flip a verified decode notices (error, repair, or changed
    // values — anything but a silently identical clean decode)
    let mut chosen = None;
    for off in (clean.len() / 4..clean.len()).step_by(61) {
        let mut c = clean.clone();
        c[off] ^= 0x08;
        let noticed = match ft::decompress_region_verified(&c, region, seq) {
            Err(_) => true,
            Ok((vals, rep)) => !rep.is_clean() || bits(&vals) != bits(&clean_vals),
        };
        if noticed {
            chosen = Some(c);
            break;
        }
    }
    let corrupt = chosen.expect("no detectable flip found");

    let path = temp_path("modec");
    std::fs::write(&path, &clean).unwrap();
    let store = ArchiveStore::with_defaults();
    let (first, r_first) = store.query(&path, region, true).unwrap();
    assert!(r_first.is_clean());
    assert_eq!(bits(&first), bits(&clean_vals));

    let g = Generation::of(&path).unwrap();
    std::fs::write(&path, &corrupt).unwrap();
    assert_ne!(Generation::of(&path).unwrap(), g, "flip must change the generation");

    let fresh = ft::decompress_region_verified(&corrupt, region, seq);
    match (store.query(&path, region, true), fresh) {
        (Err(_), Err(_)) => {} // both reject the damaged archive
        (Ok((got, rep)), Ok((want, want_rep))) => {
            assert_eq!(bits(&got), bits(&want), "store diverged from a fresh decode");
            assert_eq!(rep.blocks_reexecuted, want_rep.blocks_reexecuted);
            assert!(
                !rep.is_clean() || bits(&got) != bits(&first),
                "stale-silent: flip served as a clean unchanged decode"
            );
        }
        (store_out, fresh_out) => panic!(
            "store and fresh decode disagree on the corrupted archive: \
             store={store_out:?} fresh={fresh_out:?}"
        ),
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn concurrent_hammering_stays_byte_identical() {
    let f = field(10);
    let seq = Parallelism::Sequential;
    let ftrsz = ft::compress(&f.data, f.dims, &cfg(true)).unwrap();
    let xsz = Engine::UltraFast.codec().compress(&f.data, f.dims, &cfg(false)).unwrap();
    let p_ft = temp_path("hammer_ft");
    let p_xsz = temp_path("hammer_xsz");
    std::fs::write(&p_ft, &ftrsz).unwrap();
    std::fs::write(&p_xsz, &xsz).unwrap();

    let regions = [
        Region { origin: (0, 0, 0), shape: (8, 10, 10) },
        Region { origin: (1, 2, 3), shape: (4, 4, 4) },
        Region { origin: (7, 9, 9), shape: (1, 1, 1) },
        Region { origin: (0, 5, 0), shape: (2, 5, 10) },
    ];
    let want_ft: Vec<Vec<u32>> = regions
        .iter()
        .map(|&r| bits(&ft::decompress_region_verified(&ftrsz, r, seq).unwrap().0))
        .collect();
    let want_xsz: Vec<Vec<u32>> = regions
        .iter()
        .map(|&r| bits(&engine::decompress_region_with(&xsz, r, seq).unwrap()))
        .collect();

    // small cache + few shards: force eviction churn under contention
    let store = ArchiveStore::new(StoreConfig { cache_bytes: 1 << 20, shards: 2, workers: 1 });
    std::thread::scope(|s| {
        for t in 0..4 {
            let store = &store;
            let (p_ft, p_xsz) = (&p_ft, &p_xsz);
            let (want_ft, want_xsz) = (&want_ft, &want_xsz);
            s.spawn(move || {
                for round in 0..6 {
                    for k in 0..regions.len() {
                        // stagger the visit order per thread and round
                        let i = (k + t + round) % regions.len();
                        let region = regions[i];
                        let (got, rep) = store.query(p_ft, region, true).unwrap();
                        assert_eq!(bits(&got), want_ft[i], "ftrsz thread {t} round {round}");
                        assert!(rep.is_clean());
                        let (got, rep) = store.query(p_xsz, region, false).unwrap();
                        assert_eq!(bits(&got), want_xsz[i], "xsz thread {t} round {round}");
                        assert!(rep.is_clean());
                    }
                }
            });
        }
    });
    assert_eq!(store.stats().open_archives, 2);
    let _ = std::fs::remove_file(&p_ft);
    let _ = std::fs::remove_file(&p_xsz);
}

#[test]
fn same_tick_same_length_rewrite_is_never_served_stale() {
    // THE staleness regression: an in-place heal rewrites the file at
    // the same length, and this test pins the mtime back so (mtime, len)
    // is byte-for-byte identical to the damaged file's stamp. Only the
    // content discriminator can tell them apart — no bump_generation
    // workaround exists any more.
    let f = field(23);
    let region = Region { origin: (0, 0, 0), shape: (8, 10, 10) };
    let clean = ft::compress(&f.data, f.dims, &cfg(true)).unwrap();
    let corrupt = healable_corruption(&clean);
    assert_eq!(clean.len(), corrupt.len());
    let path = temp_path("sametick");
    std::fs::write(&path, &corrupt).unwrap();
    let m0 = std::fs::metadata(&path).unwrap().modified().unwrap();

    let store = ArchiveStore::with_defaults();
    let (d1, r1) = store.query(&path, region, true).unwrap();
    assert!(!r1.stripes_repaired.is_empty(), "open must report the at-rest damage");

    let g_damaged = Generation::of(&path).unwrap();
    parity::scrub_file(&path).unwrap();
    // force the worst case: healed file, same length, SAME mtime
    let fh = std::fs::File::options().write(true).open(&path).unwrap();
    fh.set_modified(m0).unwrap();
    fh.sync_all().unwrap();
    drop(fh);
    let g_healed = Generation::of(&path).unwrap();
    assert_eq!(g_damaged.mtime_ns, g_healed.mtime_ns, "test setup: mtimes must collide");
    assert_eq!(g_damaged.len, g_healed.len, "test setup: lengths must collide");
    assert_ne!(g_damaged, g_healed, "content stamp must discriminate the heal");

    let (d2, r2) = store.query(&path, region, true).unwrap();
    assert!(
        r2.stripes_repaired.is_empty(),
        "stale parse of the damaged generation served after a same-tick heal: {r2:?}"
    );
    assert_eq!(bits(&d1), bits(&d2), "healed decode must match the pre-heal decode");
    assert!(store.stats().invalidations >= 1, "heal never invalidated the open entry");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn fleet_scrub_heals_damage_first_and_store_serves_post_heal_bytes() {
    let dir = std::env::temp_dir().join(format!("ftsz_fleet_test_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(dir.join("sub")).unwrap();

    // RS-protected archive with TWO stripes of one group damaged — the
    // multi-stripe case XOR cannot heal
    let f = field(24);
    let rs_cfg = CompressionConfig::new(ErrorBound::Abs(1e-3))
        .with_block_size(4)
        .with_archive_parity(ParityParams::rs(64, 8, 3));
    let rs_clean = ft::compress(&f.data, f.dims, &rs_cfg).unwrap();
    let mut rs_damaged = rs_clean.clone();
    let mut rng = ftsz::util::rng::Pcg32::new(42);
    ftsz::inject::mode_c::strike(
        &mut rs_damaged,
        &mut rng,
        ftsz::inject::mode_c::ArchiveFault::GroupBurst { stripes: 2 },
    );
    assert_ne!(rs_damaged, rs_clean);
    let damaged_path = dir.join("damaged.ftsz");
    std::fs::write(&damaged_path, &rs_damaged).unwrap();

    // plus: a clean v2 archive, an unprotected v1 archive, and junk
    let clean_path = dir.join("sub").join("clean.ftsz");
    std::fs::write(&clean_path, ft::compress(&f.data, f.dims, &cfg(true)).unwrap()).unwrap();
    std::fs::write(dir.join("legacy.ftsz"), ft::compress(&f.data, f.dims, &cfg(false)).unwrap())
        .unwrap();
    std::fs::write(dir.join("notes.txt"), b"not an archive").unwrap();

    let region = Region { origin: (0, 0, 0), shape: (8, 10, 10) };
    let seq = Parallelism::Sequential;
    let want = bits(&ft::decompress_region_verified(&rs_clean, region, seq).unwrap().0);

    // prime the store on the DAMAGED generation
    let store = ArchiveStore::with_defaults();
    let (d1, r1) = store.query(&damaged_path, region, true).unwrap();
    assert_eq!(r1.stripes_repaired.len(), 2, "open must heal both damaged stripes");
    assert_eq!(bits(&d1), want);

    // dry run classifies without touching anything
    let dry = fleet::scrub_fleet(&dir, true, Some(&store)).unwrap();
    assert_eq!(dry.count("repaired"), 1);
    assert_eq!(dry.stripes_repaired(), 2);
    assert_eq!(std::fs::read(&damaged_path).unwrap(), rs_damaged, "dry run must not rewrite");

    // real pass: heals the archive and invalidates the store through
    // the scrub_path hook
    let report = fleet::scrub_fleet(&dir, false, Some(&store)).unwrap();
    assert_eq!(report.entries.len(), 3);
    assert_eq!(report.skipped, 1);
    assert_eq!(report.count("repaired"), 1);
    assert_eq!(report.count("clean"), 1);
    assert_eq!(report.count("unprotected"), 1);
    assert_eq!(report.count("unrecoverable"), 0);
    assert_eq!(report.stripes_repaired(), 2);
    // most-damaged-first ordering: the repaired entry sorts before clean
    assert!(matches!(report.entries[0].health, fleet::FleetHealth::Repaired { stripes: 2 }));
    let json = report.to_json();
    assert!(json.starts_with("{\"schema\":\"ftsz.fleet.v1\""), "{json}");
    assert!(json.contains("\"repaired\":1"), "{json}");

    // the healed file is bit-identical to the pristine archive (RS
    // erasure decode is exact) and the store serves the post-heal
    // generation with a clean report — no stale blocks
    assert_eq!(std::fs::read(&damaged_path).unwrap(), rs_clean, "heal must restore exactly");
    let (d2, r2) = store.query(&damaged_path, region, true).unwrap();
    assert!(r2.stripes_repaired.is_empty(), "store still serving the damaged generation");
    assert_eq!(bits(&d2), want);

    // second fleet pass over the healed corpus finds nothing to repair
    let second = fleet::scrub_fleet(&dir, false, Some(&store)).unwrap();
    assert_eq!(second.count("repaired"), 0);
    assert_eq!(second.count("clean"), 2);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn evict_drops_the_open_entry() {
    let f = field(11);
    let region = Region { origin: (0, 0, 0), shape: (2, 2, 2) };
    let bytes = ft::compress(&f.data, f.dims, &cfg(true)).unwrap();
    let path = temp_path("evict");
    std::fs::write(&path, &bytes).unwrap();
    let store = ArchiveStore::with_defaults();
    store.query(&path, region, true).unwrap();
    assert_eq!(store.stats().open_archives, 1);
    store.evict(&path);
    assert_eq!(store.stats().open_archives, 0);
    // and the path still serves after re-open
    store.query(&path, region, true).unwrap();
    assert_eq!(store.stats().open_archives, 1);
    let _ = std::fs::remove_file(&path);
}
