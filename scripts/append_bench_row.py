#!/usr/bin/env python3
"""Append one dated summary row of a bench run to EXPERIMENTS.md.

Usage: python3 scripts/append_bench_row.py [BENCH.json] [EXPERIMENTS.md]
       python3 scripts/append_bench_row.py --selftest

Reads a flat bench JSON and appends a markdown table row to the matching
section of EXPERIMENTS.md:

  * schema `ftsz.hotpath.v1` (written by `cargo bench --bench hotpath --
    --json`) -> the `## hotpath history` table;
  * schema `ftsz.serve.v1` (written by `ftsz serve --bench --json`) ->
    the `## serve history` table.

The row is inserted after the section's last table line — never blindly
at end-of-file, which would land hotpath rows inside the serve table (and
vice versa) now that the file holds more than one history. A missing
section is created (heading + column header) at the end of the file.
Missing keys render as `-` so schema growth never breaks the archiver.
"""

import datetime
import json
import os
import subprocess
import sys

HOTPATH_SCHEMA = "ftsz.hotpath.v1"
SERVE_SCHEMA = "ftsz.serve.v1"

SECTIONS = {
    HOTPATH_SCHEMA: "## hotpath history",
    SERVE_SCHEMA: "## serve history",
}

SERVE_HEADER = (
    "| date | commit | edge | cold p50 ms | cold p99 ms | warm p50 ms "
    "| warm p99 ms | warm × | hit % | qps w1 | qps w2 | qps w4 | qps w8 |\n"
    "|------|--------|------|-------------|-------------|-------------"
    "|-------------|--------|-------|--------|--------|--------|--------|\n"
)

HOTPATH_HEADER = (
    "| date | commit | rsz comp MB/s | ftrsz comp MB/s | xsz comp MB/s "
    "| xsz/rsz × | rsz dec MB/s | ftrsz verify MB/s | cpipe | dpipe rsz "
    "| dpipe ftrsz | vregion MB/s | parity % | rs parity % | cstream | dstream "
    "| xsz kern × | bitpack ratio |\n"
    "|------|--------|---------------|-----------------|---------------"
    "|-----------|--------------|-------------------|-------|-----------"
    "|-------------|--------------|----------|-------------|---------|---------"
    "|------------|---------------|\n"
)


def cell(m: dict, key: str, fmt: str = "{:.1f}") -> str:
    x = m.get(key)
    return fmt.format(x) if isinstance(x, (int, float)) else "-"


def hotpath_row(m: dict, date: str, commit: str) -> str:
    cells = [
        date,
        commit,
        cell(m, "rsz.compress_mbps"),
        cell(m, "ftrsz.compress_mbps"),
        cell(m, "xsz.compress_mbps"),
        cell(m, "xsz.vs_rsz_compress_speedup", "{:.2f}"),
        cell(m, "scaling.rsz_decode.w1_mbps"),
        cell(m, "scaling.ftrsz_verify.w1_mbps"),
        cell(m, "stage.rsz.speedup", "{:.2f}"),
        cell(m, "dstage.rsz.speedup", "{:.2f}"),
        cell(m, "dstage.ftrsz.speedup", "{:.2f}"),
        cell(m, "dstage.region_verified.w1_mbps"),
        cell(m, "parity.size_overhead_pct", "{:.2f}"),
        cell(m, "parity.rs.size_overhead_pct", "{:.2f}"),
        cell(m, "stream.rsz.compress_vs_inmem", "{:.2f}"),
        cell(m, "stream.rsz.decompress_vs_inmem", "{:.2f}"),
        cell(m, "kernel.quantize.speedup", "{:.2f}"),
        cell(m, "kernel.bitpack.ratio_vs_bytes", "{:.3f}"),
    ]
    return "| " + " | ".join(cells) + " |\n"


def serve_row(m: dict, date: str, commit: str) -> str:
    hit = m.get("serve.cache.hit_ratio")
    hit_pct = "{:.1f}".format(100.0 * hit) if isinstance(hit, (int, float)) else "-"
    cells = [
        date,
        commit,
        cell(m, "serve.edge", "{:.0f}"),
        cell(m, "serve.cold.p50_ms", "{:.3f}"),
        cell(m, "serve.cold.p99_ms", "{:.3f}"),
        cell(m, "serve.warm.p50_ms", "{:.3f}"),
        cell(m, "serve.warm.p99_ms", "{:.3f}"),
        cell(m, "serve.warm_speedup", "{:.1f}"),
        hit_pct,
        cell(m, "serve.qps.w1", "{:.0f}"),
        cell(m, "serve.qps.w2", "{:.0f}"),
        cell(m, "serve.qps.w4", "{:.0f}"),
        cell(m, "serve.qps.w8", "{:.0f}"),
    ]
    return "| " + " | ".join(cells) + " |\n"


def insert_row(text: str, section: str, row: str, header: str) -> str:
    """Insert `row` after the last table line of `section` (creating the
    section, with `header`, at end-of-file if absent)."""
    lines = text.splitlines(keepends=True)
    start = None
    for i, ln in enumerate(lines):
        if ln.strip() == section:
            start = i
            break
    if start is None:
        sep = "" if text.endswith("\n\n") else ("\n" if text.endswith("\n") else "\n\n")
        return text + sep + section + "\n\n" + header + row
    end = len(lines)
    for j in range(start + 1, len(lines)):
        if lines[j].startswith("## "):
            end = j
            break
    last_table = None
    for j in range(start + 1, end):
        if lines[j].lstrip().startswith("|"):
            last_table = j
    if last_table is None:
        lines.insert(end, header + row + "\n")
    else:
        lines.insert(last_table + 1, row)
    return "".join(lines)


def row_for(m: dict, date: str, commit: str):
    """(section, row, header) for a bench dict, by schema."""
    schema = m.get("schema")
    if schema == SERVE_SCHEMA:
        return SECTIONS[SERVE_SCHEMA], serve_row(m, date, commit), SERVE_HEADER
    if schema != HOTPATH_SCHEMA:
        print(f"warning: unexpected schema {schema!r}, assuming hotpath", file=sys.stderr)
    return SECTIONS[HOTPATH_SCHEMA], hotpath_row(m, date, commit), HOTPATH_HEADER


def selftest() -> int:
    date, commit = "2026-01-01", "abc1234"
    hot = {"schema": HOTPATH_SCHEMA, "rsz.compress_mbps": 101.5}
    srv = {
        "schema": SERVE_SCHEMA,
        "serve.edge": 32.0,
        "serve.cold.p50_ms": 1.234567,
        "serve.warm.p50_ms": 0.012,
        "serve.warm_speedup": 102.9,
        "serve.cache.hit_ratio": 0.987,
        "serve.qps.w1": 1000.4,
        "serve.qps.w8": 3500.9,
    }
    doc = (
        "# EXPERIMENTS\n\nprose\n\n## hotpath history\n\n"
        + HOTPATH_HEADER
        + "\n## serve history\n\n"
        + SERVE_HEADER
    )

    sec, row, hdr = row_for(hot, date, commit)
    out = insert_row(doc, sec, row, hdr)
    sec, row, hdr = row_for(srv, date, commit)
    out = insert_row(out, sec, row, hdr)

    hot_at = out.index("| 2026-01-01 | abc1234 | 101.5 |")
    serve_sec_at = out.index("## serve history")
    assert hot_at < serve_sec_at, "hotpath row landed outside its section"
    srv_at = out.index("| 2026-01-01 | abc1234 | 32 | 1.235 |")
    assert srv_at > serve_sec_at, "serve row landed outside its section"
    srv_line = out[srv_at:].splitlines()[0]
    assert " 98.7 " in srv_line, f"hit ratio not rendered as percent: {srv_line}"
    assert " 102.9 " in srv_line, f"speedup missing: {srv_line}"
    # missing keys (cold p99, warm p99, qps w2/w4) render as '-'
    assert srv_line.count(" - ") == 4, f"missing-key dashes wrong: {srv_line}"
    # a schema whose section does not exist yet gets one created at EOF
    sec, row, hdr = row_for(srv, date, commit)
    grown = insert_row("# EXPERIMENTS\n", sec, row, hdr)
    assert grown.index("## serve history") > 0 and grown.endswith(row)
    # rows append after the LAST existing row, preserving order
    sec, row2, hdr = row_for(srv, "2026-01-02", commit)
    twice = insert_row(out, sec, row2, hdr)
    assert twice.index("2026-01-02") > twice.index("| 2026-01-01 | abc1234 | 32 |")
    print("selftest OK")
    return 0


def main() -> int:
    if len(sys.argv) > 1 and sys.argv[1] == "--selftest":
        return selftest()
    bench_path = sys.argv[1] if len(sys.argv) > 1 else "rust/BENCH_hotpath.json"
    exp_path = sys.argv[2] if len(sys.argv) > 2 else "EXPERIMENTS.md"
    with open(bench_path) as f:
        m = json.load(f)

    try:
        commit = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, check=True,
        ).stdout.strip()
    except Exception:
        commit = os.environ.get("GITHUB_SHA", "unknown")[:9]

    date = datetime.date.today().isoformat()
    section, row, header = row_for(m, date, commit)
    with open(exp_path) as f:
        text = f.read()
    with open(exp_path, "w") as f:
        f.write(insert_row(text, section, row, header))
    print(f"appended to {exp_path} [{section}]: {row}", end="")
    return 0


if __name__ == "__main__":
    sys.exit(main())
