#!/usr/bin/env python3
"""Append one dated summary row of a hotpath bench run to EXPERIMENTS.md.

Usage: python3 scripts/append_bench_row.py [BENCH_hotpath.json] [EXPERIMENTS.md]

Reads the flat `ftsz.hotpath.v1` JSON the `hotpath --json` bench writes
(default: rust/BENCH_hotpath.json) and appends a markdown table row to
EXPERIMENTS.md (created by PR 4; the table header defines the columns).
Missing keys render as `-` so schema growth never breaks the archiver.
"""

import datetime
import json
import os
import subprocess
import sys


def main() -> int:
    bench_path = sys.argv[1] if len(sys.argv) > 1 else "rust/BENCH_hotpath.json"
    exp_path = sys.argv[2] if len(sys.argv) > 2 else "EXPERIMENTS.md"
    with open(bench_path) as f:
        m = json.load(f)
    if m.get("schema") != "ftsz.hotpath.v1":
        print(f"warning: unexpected schema {m.get('schema')!r}", file=sys.stderr)

    def v(key: str, fmt: str = "{:.1f}") -> str:
        x = m.get(key)
        return fmt.format(x) if isinstance(x, (int, float)) else "-"

    try:
        commit = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, check=True,
        ).stdout.strip()
    except Exception:
        commit = os.environ.get("GITHUB_SHA", "unknown")[:9]

    date = datetime.date.today().isoformat()
    row = "| {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} |\n".format(
        date,
        commit,
        v("rsz.compress_mbps"),
        v("ftrsz.compress_mbps"),
        v("xsz.compress_mbps"),
        v("xsz.vs_rsz_compress_speedup", "{:.2f}"),
        v("scaling.rsz_decode.w1_mbps"),
        v("scaling.ftrsz_verify.w1_mbps"),
        v("stage.rsz.speedup", "{:.2f}"),
        v("dstage.rsz.speedup", "{:.2f}"),
        v("dstage.ftrsz.speedup", "{:.2f}"),
        v("dstage.region_verified.w1_mbps"),
        v("parity.size_overhead_pct", "{:.2f}"),
        v("stream.rsz.compress_vs_inmem", "{:.2f}"),
        v("stream.rsz.decompress_vs_inmem", "{:.2f}"),
        v("kernel.quantize.speedup", "{:.2f}"),
        v("kernel.bitpack.ratio_vs_bytes", "{:.3f}"),
    )
    with open(exp_path, "a") as f:
        f.write(row)
    print(f"appended to {exp_path}: {row}", end="")
    return 0


if __name__ == "__main__":
    sys.exit(main())
