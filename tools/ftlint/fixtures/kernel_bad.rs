// Fixture (linted under the pretend path `compressor/kernel.rs`): the
// decode-side kernel scope — panic tokens, direct indexing of the
// untrusted packed body, and an unvalidated allocation, all inside a
// scoped unpack function. This file is test data, never compiled.

pub extern "C" fn ftsz_kernel_unpack_bits(body: &[u8], w: u32, codes: &mut [u32]) -> bool {
    let first = body[0];
    assert!(w <= 32);
    let n = (body.len() * 8) / w as usize;
    let mut scratch = vec![0u32; n * w as usize];
    scratch[0] = first as u32 + codes.first().copied().unwrap();
    panic!("unfinished");
}
