// Fixture (linted under the pretend path `compressor/kernel.rs`): the
// shapes the decode-side kernel scope must accept — length-checked
// iterator traversal of the untrusted body, shape mismatches reported by
// return value, one audited allow — plus a compress-side pack helper
// whose panicking assertion sits outside the scoped fn list and must not
// be attributed to the decode scope. This file is test data, never
// compiled.

pub extern "C" fn ftsz_kernel_unpack_bits(body: &[u8], w: u32, codes: &mut [u32]) -> bool {
    if w == 0 || w > 32 || body.len() != codes.len() * w as usize {
        return false;
    }
    let mut it = body.iter();
    for c in codes.iter_mut() {
        let Some(&b) = it.next() else { return false };
        *c = b as u32;
    }
    true
}

pub extern "C" fn ftsz_kernel_reconstruct(codes: &[u32], out: &mut [f32]) -> usize {
    let mut n = 0usize;
    for (chunk, o) in codes.chunks_exact(8).zip(out.chunks_exact_mut(8)) {
        for k in 0..8 {
            o[k] = chunk[k] as f32;
            n += 1;
        }
    }
    // ftlint::allow(r5, "capacity is clamped to one 8-lane chunk on this line")
    let mut scratch = Vec::with_capacity(n.min(8));
    scratch.push(0u32);
    n + scratch.len()
}

pub extern "C" fn ftsz_kernel_pack_bits(codes: &[u32], w: u32, out: &mut [u8]) -> bool {
    // compress side: trusted input, outside the decode-scope fn list
    assert!(w >= 1);
    let first = out.first().copied().unwrap_or(0);
    let mut staged = vec![0u8; codes.len() * w as usize];
    staged[0] = first;
    !staged.is_empty()
}
