// Fixture (linted under the pretend path `compressor/format.rs`): every
// class of R1 violation — panic tokens and direct untrusted indexing.
// This file is test data, never compiled.

pub fn parse(data: &[u8]) -> u32 {
    let magic = data[0];
    let n = u32::from_le_bytes(data[4..8].try_into().unwrap());
    if magic != 7 {
        panic!("bad magic");
    }
    match n {
        0 => unreachable!(),
        _ => {}
    }
    assert_eq!(n % 2, 0);
    n
}
