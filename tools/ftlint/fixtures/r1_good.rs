// Fixture (linted under the pretend path `compressor/format.rs`): the
// panic-free shape of the same parse — R1 must stay silent, including on
// debug_assert!, test-module unwraps, and an audited allow.
// This file is test data, never compiled.

pub fn parse(data: &[u8]) -> Result<u32, ()> {
    let magic = *data.get(0).ok_or(())?;
    debug_assert!(magic < 255, "internal invariant only");
    let raw = data.get(4..8).ok_or(())?;
    let n = u32::from_le_bytes(raw.try_into().map_err(|_| ())?);
    // ftlint::allow(r1, "index 0 re-checked by the get() two lines above")
    let first = data[0];
    let _ = first;
    Ok(n)
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_unwrap_freely() {
        super::parse(&[7, 0, 0, 0, 1, 0, 0, 0]).unwrap();
        assert_eq!(1 + 1, 2);
    }
}
