// Fixture (linted under the pretend path `compressor/rogue.rs`): a scoped
// thread spawn outside the R2 allowlist must trip the thread-scope
// single-site invariant. This file is test data, never compiled.

pub fn run_parallel(xs: &mut [u32]) {
    std::thread::scope(|s| {
        for x in xs.iter_mut() {
            s.spawn(move || *x += 1);
        }
    });
}
