// Fixture (linted under the pretend path `coordinator/pipeline.rs`): the
// allowlist grants this file exactly one thread::scope site, and exactly
// one exists — R2 must stay silent. A second mention inside #[cfg(test)]
// must not count. This file is test data, never compiled.

pub fn fan_out(ranks: usize) {
    std::thread::scope(|s| {
        for _ in 0..ranks {
            s.spawn(|| {});
        }
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_is_exempt() {
        std::thread::scope(|s| {
            s.spawn(|| {});
        });
    }
}
