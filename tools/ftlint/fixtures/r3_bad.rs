// Fixture (linted under the pretend path `ft/checksum.rs`): bare
// arithmetic on checksum accumulators — both compound assignment and a
// binary operand position must trip R3. This file is test data, never
// compiled.

pub fn fold(acc: u64, x: u64) -> u64 {
    let mut sum = acc;
    sum += x;
    let delta = x * 3;
    sum - delta
}
