// Fixture (linted under the pretend path `ft/checksum.rs`): the same
// algebra written with wrapping_* — R3 must stay silent, including on
// non-arithmetic neighbors (calls, comparisons, unary negation of a
// non-accumulator). This file is test data, never compiled.

pub fn fold(acc: u64, x: u64) -> u64 {
    let mut sum = acc;
    sum = sum.wrapping_add(x);
    let delta = x.wrapping_mul(3);
    if sum == delta {
        return sum;
    }
    sum.wrapping_sub(delta)
}
