// Fixture (linted under the pretend path `util/rogue.rs`): any `unsafe`
// outside the io/posix.rs carve-out must trip R4. This file is test data,
// never compiled.

pub fn peek(p: *const u8) -> u8 {
    unsafe { *p }
}
