// Fixture (linted under the pretend path `io/posix.rs`): unsafe is
// tolerated in the carve-out file when justified by a SAFETY: comment —
// R4 must stay silent. This file is test data, never compiled.

pub fn read_at(fd: i32, buf: &mut [u8]) -> isize {
    // SAFETY: fd is owned by the enclosing handle for this call's whole
    // duration, and the pointer/len pair comes from a live &mut slice, so
    // the kernel cannot write out of bounds.
    unsafe { pread_shim(fd, buf.as_mut_ptr(), buf.len()) }
}
