// Fixture (linted under the pretend path `compressor/format.rs`):
// decode-scope allocations sized by a plain variable that could trace
// back to raw header bytes — both with_capacity and vec![..; n] must
// trip R5. This file is test data, never compiled.

pub fn parse(data: &[u8]) -> Vec<u8> {
    let n_blocks = data.len() / 8 + 1;
    let mut out = Vec::with_capacity(n_blocks);
    out.extend(vec![0u8; n_blocks]);
    out
}
