// Fixture (linted under the pretend path `compressor/format.rs`): the
// validated allocation shapes — .len() of a checked slice, literal sizes,
// SCREAMING_CASE clamp constants, and one audited allow for a value the
// linter cannot see is clamped. R5 must stay silent. This file is test
// data, never compiled.

const MAX_BLOCKS: usize = 1 << 20;

pub fn parse(data: &[u8]) -> Vec<u32> {
    let mut lens = Vec::with_capacity(data.len() / 8);
    lens.resize(data.len() / 8, 0u32);
    let mut lut = vec![0u32; MAX_BLOCKS];
    let fixed = vec![0u32; 1 << 12];
    let n_blocks = data.len().min(MAX_BLOCKS);
    // ftlint::allow(r5, "n_blocks is clamped to MAX_BLOCKS on the line above")
    let mut out = Vec::with_capacity(n_blocks);
    out.append(&mut lut);
    out.extend(fixed);
    out.extend(lens);
    out
}
