// Fixture (linted under the pretend path `compressor/store/protocol.rs`):
// the serve wire surface — panic tokens, direct indexing of the untrusted
// request fields, and an allocation sized straight from a client-supplied
// count, all inside scoped parsing functions. This file is test data,
// never compiled.

pub fn parse_request(line: &str) -> u32 {
    let parts: Vec<&str> = line.split_whitespace().collect();
    let cmd = parts[0];
    assert_eq!(cmd, "QUERY");
    let n: usize = parts[1].parse().unwrap();
    let mut payload = Vec::with_capacity(n * 4);
    payload.push(0u8);
    panic!("unfinished request {line}");
}

pub fn parse_response_header(line: &str) -> usize {
    let head = &line[..2];
    if head == "OK" {
        line.len()
    } else {
        unreachable!("server spoke garbage")
    }
}
