// Fixture (linted under the pretend path `compressor/store/protocol.rs`):
// the shapes the serve wire scope must accept — iterator field walking
// with clean-error returns, a cap-checked payload count — plus a
// writer-side renderer whose unwrap sits outside the scoped fn list and
// must not be attributed to the wire scope. This file is test data, never
// compiled.

pub fn parse_request(line: &str) -> Option<(u32, bool)> {
    let mut fields = line.split_whitespace();
    let n: u32 = fields.next()?.parse().ok()?;
    let verify = matches!(fields.next(), Some("verify"));
    if fields.next().is_some() {
        return None; // trailing fields: clean reject, never a panic
    }
    Some((n, verify))
}

pub fn parse_response_header(line: &str) -> Option<usize> {
    let mut fields = line.split_whitespace();
    let values: usize = fields.next()?.parse().ok()?;
    if values as u128 > MAX_DECODED_POINTS {
        return None; // announced payload over the decode cap
    }
    Some(values)
}

pub fn ok_header(values: usize, reexec: usize) -> String {
    // writer side: trusted server state, outside the decode-scope fn list
    use std::fmt::Write;
    let mut s = String::new();
    write!(s, "OK {values} reexec={reexec}").unwrap();
    s
}
