//! The repo-specific lint configuration: which modules are untrusted-input
//! decode surfaces (R1/R5), which buffers in them hold attacker-shaped
//! bytes, and where the single-site architecture invariants (R2) live.
//!
//! This table IS the enforcement contract — editing it is how a PR that
//! legitimately moves an invariant keeps the lint honest, and the diff on
//! this file is the reviewer's audit trail.

/// R1/R5 scope of one untrusted-input module.
pub struct ModuleScope {
    /// Path relative to the linted source root.
    pub path: &'static str,
    /// Functions whose bodies are in R1 scope; `None` = the whole file
    /// (minus `#[cfg(test)]` items).
    pub r1_fns: Option<&'static [&'static str]>,
    /// Functions whose bodies are in R5 (guarded-allocation) scope;
    /// `None` = same as `r1_fns`.
    pub r5_fns: Option<&'static [&'static str]>,
    /// Identifiers holding untrusted bytes/derived arrays: direct
    /// `ident[...]` indexing on these is an R1 finding (use `.get()`, a
    /// bounds-checked cursor, or an audited allow).
    pub untrusted: &'static [&'static str],
}

/// The untrusted-input decode surface (paper §5: a panic on
/// attacker-shaped bytes silently breaks the corrected / clean-error /
/// never-silent trichotomy).
pub const DECODE_SCOPES: &[ModuleScope] = &[
    ModuleScope {
        // container parse: every byte is untrusted until the voted header
        // and section CRCs vouch for it
        path: "compressor/format.rs",
        r1_fns: None,
        r5_fns: Some(&[
            "parse",
            "peek_header",
            "parse_v1",
            "parse_v2",
            "parse_v2_with",
            "read_v2_prelude",
            "read_section",
            "read_core_fields",
            "assemble",
            "transcode_v1_to_v2",
        ]),
        untrusted: &[
            "data",
            "payload",
            "unpred",
            "unpred_raw",
            "meta_raw",
            "ft_raw",
            "body",
            "payload_offsets",
            "unpred_offsets",
        ],
    },
    ModuleScope {
        // the whole decode stage graph runs downstream of a hostile parse
        path: "compressor/destage.rs",
        r1_fns: None,
        r5_fns: None,
        untrusted: &["sums", "metas"],
    },
    ModuleScope {
        // parity recovery reads raw stored bytes before any CRC has passed;
        // build()/put-side helpers are writer-side and out of scope
        path: "ft/parity.rs",
        r1_fns: Some(&[
            "recover",
            "recover_with",
            "looks_v2",
            "scrub",
            "scrub_file",
            "parse_recovering",
            "stripe_of",
            "u32_at",
            "decode_geometry",
            "rs_rebuild_group",
            "put_healed_stripe",
            "gf_mul",
            "gf_pow_alpha",
            "gf_inv",
        ]),
        r5_fns: None,
        untrusted: &[
            "data",
            "parity_body",
            "protected",
            "blobs",
            "stripe_crcs",
            "healed",
            "per_group",
        ],
    },
    ModuleScope {
        // decode side only: the table builders validate Kraft consistency
        // at construction, so decode()'s table-internal indexing is
        // bounds-safe by construction — the untrusted set is empty and the
        // panic-token scan is the active check here
        path: "compressor/huffman.rs",
        r1_fns: Some(&["decode", "decode_slow", "deserialize", "from_lengths"]),
        r5_fns: None,
        untrusted: &[],
    },
    ModuleScope {
        // xsz's decode stage (tag dispatch + the shared fixed-point fill);
        // compress side is trusted-input
        path: "compressor/xsz.rs",
        r1_fns: Some(&["decode_block", "fill_from_codes"]),
        r5_fns: None,
        untrusted: &[],
    },
    ModuleScope {
        // the chunked xsz kernels: the unpack/reconstruct halves run on
        // attacker-shaped payload bytes (destage → xsz::decode_block →
        // here). All traversal is length-checked chunk iterators; shape
        // mismatches are reported by return value, never by panic.
        path: "compressor/kernel.rs",
        r1_fns: Some(&[
            "ftsz_kernel_unpack_bytes",
            "unpack_bytes_n",
            "ftsz_kernel_unpack_bits",
            "unpack_bits_stream",
            "ftsz_kernel_reconstruct",
            "ftsz_kernel_reconstruct_scalar",
        ]),
        r5_fns: None,
        untrusted: &["body"],
    },
    ModuleScope {
        // streaming decode: the slab placer and the reduction sinks; the
        // compress-side slab cursor is trusted-input. Buffer indexing here
        // goes through checked_add/.get patterns, hence the empty set.
        path: "compressor/stream.rs",
        r1_fns: Some(&["open_slab", "flush", "place", "close", "put"]),
        r5_fns: None,
        untrusted: &[],
    },
    ModuleScope {
        // the server's wire surface: request lines and response headers
        // arrive from arbitrary clients, so framing and field parsing must
        // be panic-free and allocation-capped before anything touches the
        // store. Writer-side formatting (ok_header, payload_bytes) is
        // trusted-output and out of scope.
        path: "compressor/store/protocol.rs",
        r1_fns: Some(&[
            "read_request_line",
            "parse_request",
            "parse_region",
            "parse_region_list",
            "parse_response_header",
        ]),
        r5_fns: None,
        untrusted: &["line", "buf", "parts", "fields"],
    },
];

/// One R2 single-site invariant: a pattern that may appear in non-test
/// code only at the allowlisted (file, exact count) sites.
pub struct SingleSite {
    /// Rule sub-name for reporting.
    pub name: &'static str,
    /// Substring matched against blanked code lines.
    pub pattern: &'static str,
    /// (file, exact non-test occurrence count) — any other file: zero.
    pub allowed: &'static [(&'static str, usize)],
    /// One-line fix hint.
    pub hint: &'static str,
}

/// The single-site architecture invariants (CHANGES.md's "grep-provable"
/// claims, now machine-checked).
pub const SINGLE_SITES: &[SingleSite] = &[
    SingleSite {
        name: "thread-scope",
        pattern: "thread::scope",
        allowed: &[
            // the one pipeline driver trio
            ("compressor/chain.rs", 1),
            // the pool substrate: parallel_chunks + parallel_map
            ("util/threadpool.rs", 2),
            // the coordinator's rank fan-out
            ("coordinator/pipeline.rs", 1),
        ],
        hint: "route new pipelines through compressor::chain instead of \
               spawning scoped threads in place",
    },
    SingleSite {
        name: "reexec-count",
        pattern: "blocks_reexecuted +=",
        allowed: &[
            // the one ordered-commit per-block fold
            ("compressor/destage.rs", 1),
            // DecompressReport::absorb merges reports destage already
            // folded (serving-layer bookkeeping, not a new fold site)
            ("ft/report.rs", 1),
        ],
        hint: "report re-execution repairs via destage::fold_block_outcome, \
               the one ordered-commit fold",
    },
    SingleSite {
        name: "verify-stage",
        pattern: "fn verify_stage",
        allowed: &[("compressor/destage.rs", 1)],
        hint: "there is exactly one Algorithm-2 verify/re-execute loop body; \
               parameterize destage::verify_stage instead of copying it",
    },
];

/// R3: file whose mod-2^64 accumulator algebra must be `wrapping_*`.
pub const CHECKSUM_FILE: &str = "ft/checksum.rs";

/// R3: identifiers that carry mod-2^64 accumulator values; a bare
/// `+`/`-`/`*` (or compound assignment) touching one is a finding.
pub const CHECKSUM_ACCUMULATORS: &[&str] =
    &["sum", "isum", "delta", "ds", "di", "w", "w_old", "w_new"];

/// R4: the one module allowed to contain `unsafe` (with `// SAFETY:`).
pub const UNSAFE_ALLOWED_FILE: &str = "io/posix.rs";

/// R4 meta-check: the crate root must carry this attribute.
pub const FORBID_UNSAFE_ATTR: &str = "#![forbid(unsafe_code)]";

/// Look up the R1/R5 scope for a file.
pub fn scope_for(rel_path: &str) -> Option<&'static ModuleScope> {
    DECODE_SCOPES.iter().find(|s| s.path == rel_path)
}
