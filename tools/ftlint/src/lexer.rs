//! A line-oriented Rust pseudo-lexer: just enough lexical structure for
//! the structural lints — comment/string stripping (so patterns never
//! match inside literals or docs), nested block comments, raw strings,
//! char-vs-lifetime disambiguation, `#[cfg(test)]` item skipping, and
//! enclosing-`fn` attribution via brace tracking.
//!
//! This is deliberately NOT a full parser. The rules it feeds are
//! substring/token checks whose false-positive escape hatch is an audited
//! `// ftlint::allow(rule, "reason")` comment, so the lexer only has to be
//! conservative and deterministic, not complete.

/// One source line after lexing.
#[derive(Debug)]
pub struct Line {
    /// 1-based line number.
    pub number: usize,
    /// The line's code with comments, string/char literal *contents*
    /// blanked to spaces (structure like quotes is also blanked). Length
    /// is not preserved exactly; only token adjacency matters.
    pub code: String,
    /// Concatenated comment text appearing on this line (line + block).
    pub comment: String,
    /// True when the line sits inside a `#[cfg(test)]`-gated item (or is
    /// the attribute line itself).
    pub in_test: bool,
    /// Name of the innermost named `fn` whose body covers this line.
    pub fn_name: Option<String>,
}

/// A lexed source file.
#[derive(Debug)]
pub struct SourceFile {
    /// Path relative to the linted source root (e.g. `compressor/format.rs`).
    pub rel_path: String,
    /// Lexed lines, in order.
    pub lines: Vec<Line>,
}

#[derive(Clone, Copy, PartialEq)]
enum State {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(usize),
}

/// Phase 1: split into per-line (code, comment) with literals blanked.
fn strip(content: &str) -> Vec<(String, String)> {
    let mut out: Vec<(String, String)> = Vec::new();
    let mut state = State::Code;
    for raw_line in content.split('\n') {
        let chars: Vec<char> = raw_line.chars().collect();
        let mut code = String::with_capacity(chars.len());
        let mut comment = String::new();
        let mut i = 0usize;
        // a line comment never spans lines
        if state == State::LineComment {
            state = State::Code;
        }
        while i < chars.len() {
            let c = chars[i];
            let next = chars.get(i + 1).copied();
            match state {
                State::Code => match c {
                    '/' if next == Some('/') => {
                        comment.push_str(&raw_line[char_byte(raw_line, i)..]);
                        state = State::LineComment;
                        i = chars.len();
                    }
                    '/' if next == Some('*') => {
                        state = State::BlockComment(1);
                        code.push(' ');
                        i += 2;
                    }
                    '"' => {
                        state = State::Str;
                        code.push(' ');
                        i += 1;
                    }
                    'r' | 'b' if is_raw_string_start(&chars, i) => {
                        let (hashes, skip) = raw_string_open(&chars, i);
                        state = State::RawStr(hashes);
                        code.push(' ');
                        i += skip;
                    }
                    '\'' => {
                        // char literal vs lifetime: '\...' or 'x' is a char;
                        // 'ident (no closing quote right after) is a lifetime
                        if next == Some('\\') {
                            // skip escaped char literal: '\X' or '\u{..}'
                            let mut j = i + 2;
                            while j < chars.len() && chars[j] != '\'' {
                                j += 1;
                            }
                            code.push(' ');
                            i = (j + 1).min(chars.len());
                        } else if chars.get(i + 2) == Some(&'\'') {
                            code.push(' ');
                            i += 3;
                        } else {
                            code.push('\''); // lifetime, keep as code
                            i += 1;
                        }
                    }
                    _ => {
                        code.push(c);
                        i += 1;
                    }
                },
                State::LineComment => unreachable!("consumed above"),
                State::BlockComment(depth) => {
                    if c == '*' && next == Some('/') {
                        state = if depth == 1 {
                            State::Code
                        } else {
                            State::BlockComment(depth - 1)
                        };
                        i += 2;
                    } else if c == '/' && next == Some('*') {
                        state = State::BlockComment(depth + 1);
                        i += 2;
                    } else {
                        comment.push(c);
                        i += 1;
                    }
                }
                State::Str => {
                    if c == '\\' {
                        i += 2; // skip the escaped char (incl. \")
                    } else if c == '"' {
                        state = State::Code;
                        i += 1;
                    } else {
                        i += 1;
                    }
                    code.push(' ');
                }
                State::RawStr(hashes) => {
                    if c == '"' && closes_raw(&chars, i, hashes) {
                        state = State::Code;
                        i += 1 + hashes;
                    } else {
                        i += 1;
                    }
                    code.push(' ');
                }
            }
        }
        out.push((code, comment));
    }
    out
}

/// Byte offset of char index `i` in `s` (for slicing comment tails).
fn char_byte(s: &str, i: usize) -> usize {
    s.char_indices().nth(i).map(|(b, _)| b).unwrap_or(s.len())
}

/// True when `chars[i..]` opens a raw string (`r"`, `r#"`, `br#"` …) and
/// `i` is not the tail of a longer identifier.
fn is_raw_string_start(chars: &[char], i: usize) -> bool {
    if i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_') {
        return false;
    }
    let mut j = i;
    if chars[j] == 'b' {
        j += 1;
        if chars.get(j) != Some(&'r') {
            return false;
        }
    }
    if chars.get(j) != Some(&'r') {
        return false;
    }
    j += 1;
    while chars.get(j) == Some(&'#') {
        j += 1;
    }
    chars.get(j) == Some(&'"')
}

/// (number of hashes, chars consumed) of a raw-string opener at `i`.
fn raw_string_open(chars: &[char], i: usize) -> (usize, usize) {
    let mut j = i;
    if chars[j] == 'b' {
        j += 1;
    }
    j += 1; // the 'r'
    let mut hashes = 0;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    j += 1; // the opening quote
    (hashes, j - i)
}

/// True when the `"` at `i` is followed by `hashes` `#` chars.
fn closes_raw(chars: &[char], i: usize, hashes: usize) -> bool {
    (1..=hashes).all(|k| chars.get(i + k) == Some(&'#'))
}

/// A brace frame: the item/block it opened, and whether it is test-gated
/// or a named fn body.
struct Frame {
    fn_name: Option<String>,
    is_test: bool,
}

/// Phase 2: structural annotation (test regions, enclosing fn).
pub fn lex(rel_path: &str, content: &str) -> SourceFile {
    let stripped = strip(content);
    let mut lines = Vec::with_capacity(stripped.len());
    let mut frames: Vec<Frame> = Vec::new();
    // set by `#[cfg(test)]`, consumed by the next `{` (or dropped at `;`)
    let mut pending_test = false;
    // set by `fn name`, consumed by the next `{` (or dropped at `;`)
    let mut pending_fn: Option<String> = None;

    for (li, (code, comment)) in stripped.into_iter().enumerate() {
        let mut in_test =
            pending_test || frames.iter().any(|f| f.is_test);
        let mut fn_name = innermost_fn(&frames);

        if code.contains("#[cfg(test)]") {
            pending_test = true;
            in_test = true;
        }
        if let Some(name) = find_fn_decl(&code) {
            pending_fn = Some(name);
        }
        let chars: Vec<char> = code.chars().collect();
        for &c in &chars {
            match c {
                '{' => {
                    frames.push(Frame {
                        fn_name: pending_fn.take(),
                        is_test: pending_test,
                    });
                    pending_test = false;
                    if frames.iter().any(|f| f.is_test) {
                        in_test = true;
                    }
                    if let Some(n) = innermost_fn(&frames) {
                        fn_name = Some(n);
                    }
                }
                '}' => {
                    frames.pop();
                }
                ';' if frames.is_empty() || pending_fn.is_some() || pending_test => {
                    // item ended without a body: drop pending attributions
                    // (e.g. `#[cfg(test)] use x;`, trait fn declarations)
                    pending_fn = None;
                    pending_test = false;
                }
                _ => {}
            }
        }
        lines.push(Line {
            number: li + 1,
            code,
            comment,
            in_test,
            fn_name,
        });
    }
    SourceFile { rel_path: rel_path.to_string(), lines }
}

fn innermost_fn(frames: &[Frame]) -> Option<String> {
    frames.iter().rev().find_map(|f| f.fn_name.clone())
}

/// Find `fn <name>` in a code line (declaration position, not `fn(` type
/// syntax). Returns the last declaration on the line.
fn find_fn_decl(code: &str) -> Option<String> {
    let bytes = code.as_bytes();
    let mut found = None;
    let mut i = 0;
    while let Some(off) = code[i..].find("fn ") {
        let at = i + off;
        i = at + 3;
        // word boundary on the left ("fn" not a tail of an identifier)
        if at > 0 {
            let prev = bytes[at - 1] as char;
            if prev.is_alphanumeric() || prev == '_' {
                continue;
            }
        }
        let rest = code[at + 3..].trim_start();
        let name: String = rest
            .chars()
            .take_while(|c| c.is_alphanumeric() || *c == '_')
            .collect();
        if !name.is_empty() {
            found = Some(name);
        }
    }
    found
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_blanked() {
        let f = lex(
            "x.rs",
            "let a = \"panic!\"; // unwrap() in a comment\nlet b = 'c';",
        );
        assert!(!f.lines[0].code.contains("panic!"));
        assert!(f.lines[0].comment.contains("unwrap()"));
        assert!(!f.lines[1].code.contains('c') || !f.lines[1].code.contains("'c'"));
    }

    #[test]
    fn lifetimes_survive_char_stripping() {
        let f = lex("x.rs", "fn f<'a>(x: &'a [u8]) -> &'a [u8] { x }");
        assert!(f.lines[0].code.contains("'a"));
    }

    #[test]
    fn raw_strings_are_blanked() {
        let f = lex("x.rs", "let s = r#\"unwrap() \" panic!\"#; s.len();");
        assert!(!f.lines[0].code.contains("unwrap"));
        assert!(f.lines[0].code.contains(".len()"));
    }

    #[test]
    fn nested_block_comments() {
        let f = lex("x.rs", "a /* one /* two */ still */ b");
        let code = &f.lines[0].code;
        assert!(code.contains('a') && code.contains('b'));
        assert!(!code.contains("still"));
    }

    #[test]
    fn cfg_test_region_is_marked() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\n\
                   fn live2() {}\n";
        let f = lex("x.rs", src);
        assert!(!f.lines[0].in_test);
        assert!(f.lines[1].in_test);
        assert!(f.lines[2].in_test);
        assert!(f.lines[3].in_test);
        assert!(!f.lines[5].in_test, "code after the test mod is live again");
    }

    #[test]
    fn enclosing_fn_attribution() {
        let src = "fn outer() {\n    let c = |x: u32| {\n        x + 1\n    };\n}\n\
                   fn other() {\n    1;\n}\n";
        let f = lex("x.rs", src);
        assert_eq!(f.lines[2].fn_name.as_deref(), Some("outer"));
        assert_eq!(f.lines[6].fn_name.as_deref(), Some("other"));
    }

    #[test]
    fn trait_decl_does_not_leak_fn_name() {
        let src = "trait T {\n    fn sig(&self);\n}\nstruct S;\n";
        let f = lex("x.rs", src);
        assert_eq!(f.lines[3].fn_name, None);
    }
}
