//! ftlint — in-tree static analysis for the `ftsz` crate.
//!
//! Enforces the repo's SDC-resilience invariants structurally, as a
//! CI-blocking pass (`cargo run -p ftlint`):
//!
//! - **R1 decode-path panic-freedom** — no `unwrap`/`expect`/panicking
//!   macros/direct untrusted-buffer indexing in the untrusted-input
//!   decode modules ([`config::DECODE_SCOPES`]); `debug_assert*` allowed.
//! - **R2 single-site invariants** — `thread::scope`, the
//!   `blocks_reexecuted` fold, and `fn verify_stage` exist exactly at
//!   their allowlisted sites ([`config::SINGLE_SITES`]).
//! - **R3 wrapping checksum algebra** — `ft/checksum.rs` accumulators use
//!   `wrapping_*`, never bare `+`/`-`/`*`.
//! - **R4 unsafe inventory** — crate root keeps `#![forbid(unsafe_code)]`;
//!   `unsafe` only ever in `io/posix.rs` with a `// SAFETY:` comment.
//! - **R5 guarded allocation** — decode-scope allocations sized only by
//!   validated quantities (`.len()`, literals, `MAX_*` constants).
//!
//! False positives are silenced by an audited escape hatch,
//! `// ftlint::allow(rule, "reason")`, which itself is linted: the reason
//! must be non-empty and the allow must actually suppress something.
//!
//! The linter is a deliberate pseudo-lexer (see [`lexer`]), not a parser:
//! it blanks comments/strings, tracks `#[cfg(test)]` regions and
//! enclosing functions by brace counting, and runs substring/token rules.
//! That is enough for these invariants, keeps the tool at zero external
//! dependencies (the build image is offline), and fails conservative —
//! anything it cannot prove quiet shows up as a finding with a fix hint.

pub mod config;
pub mod lexer;
pub mod rules;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

pub use rules::Finding;

/// Lint one source text under a pretend tree-relative path (so the scope
/// tables apply). This is the entry point the fixture self-tests use.
pub fn lint_source(rel_path: &str, content: &str) -> Vec<Finding> {
    rules::run_file(&lexer::lex(rel_path, content))
}

/// The crate tree this repo checks: `rust/src`, located relative to the
/// ftlint manifest so the binary works from any working directory.
pub fn default_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../../rust/src")
}

/// Lint every `.rs` file under `root`. Findings are sorted by
/// (file, line) for deterministic output.
pub fn lint_tree(root: &Path) -> io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    walk(root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for path in files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let content = fs::read_to_string(&path)?;
        out.extend(lint_source(&rel, &content));
    }
    out.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(out)
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}
