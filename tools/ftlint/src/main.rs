//! CLI: `cargo run -p ftlint [-- --json] [--root PATH]`
//!
//! Exit status 0 when the tree is clean, 1 when any finding (or an I/O
//! error) occurred — CI wires this as a blocking job. `--json`
//! additionally writes `LINT_report.json` to the working directory for
//! artifact upload.

use std::path::PathBuf;
use std::process::ExitCode;

use ftlint::{default_root, lint_tree, Finding};

fn main() -> ExitCode {
    let mut json = false;
    let mut root = default_root();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--json" => json = true,
            "--root" => match args.next() {
                Some(p) => root = PathBuf::from(p),
                None => {
                    eprintln!("ftlint: --root needs a path");
                    return ExitCode::FAILURE;
                }
            },
            "--help" | "-h" => {
                println!(
                    "ftlint — structural lints for rust/src\n\
                     usage: cargo run -p ftlint [-- --json] [--root PATH]\n\
                     --json   also write LINT_report.json to the CWD\n\
                     --root   lint this tree instead of rust/src"
                );
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("ftlint: unknown argument `{other}`");
                return ExitCode::FAILURE;
            }
        }
    }

    let findings = match lint_tree(&root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("ftlint: cannot lint {}: {e}", root.display());
            return ExitCode::FAILURE;
        }
    };

    for f in &findings {
        println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message);
        println!("    fix: {}", f.hint);
    }

    if json {
        let report = render_json(&root.display().to_string(), &findings);
        if let Err(e) = std::fs::write("LINT_report.json", report) {
            eprintln!("ftlint: cannot write LINT_report.json: {e}");
            return ExitCode::FAILURE;
        }
        eprintln!("ftlint: wrote LINT_report.json");
    }

    if findings.is_empty() {
        eprintln!("ftlint: clean ({} ok)", root.display());
        ExitCode::SUCCESS
    } else {
        eprintln!("ftlint: {} finding(s)", findings.len());
        ExitCode::FAILURE
    }
}

/// Hand-rolled JSON (the crate has zero dependencies by design).
fn render_json(root: &str, findings: &[Finding]) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"root\": {},\n", quote(root)));
    s.push_str(&format!("  \"count\": {},\n", findings.len()));
    s.push_str("  \"findings\": [");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str("\n    {");
        s.push_str(&format!("\"rule\": {}, ", quote(f.rule)));
        s.push_str(&format!("\"file\": {}, ", quote(&f.file)));
        s.push_str(&format!("\"line\": {}, ", f.line));
        s.push_str(&format!("\"message\": {}, ", quote(&f.message)));
        s.push_str(&format!("\"hint\": {}", quote(&f.hint)));
        s.push('}');
    }
    if !findings.is_empty() {
        s.push_str("\n  ");
    }
    s.push_str("]\n}\n");
    s
}

fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}
