//! Rule engine: findings, the audited `ftlint::allow` escape hatch, and
//! per-file dispatch of the five rule families.

use crate::lexer::SourceFile;

pub mod r1_panic;
pub mod r2_single_site;
pub mod r3_wrapping;
pub mod r4_unsafe;
pub mod r5_alloc;

/// One lint finding: file:line, what, and how to fix it.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Rule id: `r1`..`r5`, or `allow` for escape-hatch misuse.
    pub rule: &'static str,
    /// Path relative to the linted source root.
    pub file: String,
    /// 1-based line.
    pub line: usize,
    /// What was found.
    pub message: String,
    /// One-line fix hint.
    pub hint: String,
}

/// A parsed `// ftlint::allow(rule, "reason")` comment.
struct AllowEntry {
    line: usize,
    rule: String,
    used: bool,
}

/// The audited escape hatch: an allow comment suppresses findings of its
/// rule on the same line or the line directly below (comment-above
/// style). A missing or empty reason string, and an allow that suppressed
/// nothing, are themselves findings — allows must stay justified and live.
pub struct Allows {
    entries: Vec<AllowEntry>,
    /// Malformed allows, reported immediately.
    pub findings: Vec<Finding>,
}

impl Allows {
    /// Scan a lexed file's comments for allow annotations.
    pub fn collect(file: &SourceFile) -> Self {
        let mut entries = Vec::new();
        let mut findings = Vec::new();
        for line in &file.lines {
            let Some(at) = line.comment.find("ftlint::allow(") else {
                continue;
            };
            let args = &line.comment[at + "ftlint::allow(".len()..];
            let parsed = parse_allow_args(args);
            match parsed {
                Some(rule) => entries.push(AllowEntry {
                    line: line.number,
                    rule,
                    used: false,
                }),
                None => findings.push(Finding {
                    rule: "allow",
                    file: file.rel_path.clone(),
                    line: line.number,
                    message: "malformed ftlint::allow — needs a rule and a \
                              non-empty quoted reason"
                        .into(),
                    hint: "write `// ftlint::allow(rN, \"why this site is safe\")`"
                        .into(),
                }),
            }
        }
        Self { entries, findings }
    }

    /// True (and marks the allow used) when a finding of `rule` on `line`
    /// is covered by an allow on the same or the previous line.
    pub fn suppress(&mut self, rule: &str, line: usize) -> bool {
        for e in &mut self.entries {
            if e.rule == rule && (e.line == line || e.line + 1 == line) {
                e.used = true;
                return true;
            }
        }
        false
    }

    /// Findings for allows that suppressed nothing (dead annotations rot
    /// into false confidence — they must be removed with the fix).
    pub fn unused(&self, file: &str) -> Vec<Finding> {
        self.entries
            .iter()
            .filter(|e| !e.used)
            .map(|e| Finding {
                rule: "allow",
                file: file.to_string(),
                line: e.line,
                message: format!(
                    "ftlint::allow({}) suppressed no finding — stale annotation",
                    e.rule
                ),
                hint: "delete the allow (or fix its rule id)".into(),
            })
            .collect()
    }
}

/// Parse `rule, "reason")` — returns the rule id only when the reason is
/// a non-empty string literal followed by the closing paren. The reason is
/// located by its quotes, not by the first `)`, so reasons may mention
/// calls like `.len()`.
fn parse_allow_args(args: &str) -> Option<String> {
    let comma = args.find(',')?;
    let rule = args[..comma].trim();
    if rule.is_empty() || !rule.chars().all(|c| c.is_alphanumeric() || c == '_') {
        return None;
    }
    let rest = args[comma + 1..].trim_start().strip_prefix('"')?;
    let endq = rest.find('"')?;
    if rest[..endq].trim().is_empty() {
        return None;
    }
    rest[endq + 1..].trim_start().strip_prefix(')')?;
    Some(rule.to_string())
}

/// Run every per-file rule over one lexed file.
pub fn run_file(file: &SourceFile) -> Vec<Finding> {
    let mut allows = Allows::collect(file);
    let mut out = Vec::new();
    out.extend(allows.findings.drain(..));
    r1_panic::run(file, &mut allows, &mut out);
    r2_single_site::run(file, &mut allows, &mut out);
    r3_wrapping::run(file, &mut allows, &mut out);
    r4_unsafe::run(file, &mut allows, &mut out);
    r5_alloc::run(file, &mut allows, &mut out);
    out.extend(allows.unused(&file.rel_path));
    out
}

// ---------------------------------------------------------------------------
// shared token helpers
// ---------------------------------------------------------------------------

/// True when byte `i` of `code` starts `pat` at an identifier boundary on
/// the left (so `debug_assert!` never matches `assert!`).
pub(crate) fn word_start(code: &str, i: usize, _pat: &str) -> bool {
    if i == 0 {
        return true;
    }
    let prev = code.as_bytes()[i - 1] as char;
    !(prev.is_alphanumeric() || prev == '_' || prev == '.')
}

/// First non-space char at or after byte `i`.
pub(crate) fn next_nonspace(code: &str, i: usize) -> Option<char> {
    code[i..].chars().find(|c| !c.is_whitespace())
}

/// Iterator over (byte offset, identifier) words of a code line.
pub(crate) fn idents(code: &str) -> Vec<(usize, &str)> {
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i < bytes.len() {
        let c = bytes[i] as char;
        if c.is_ascii_alphabetic() || c == '_' {
            let start = i;
            while i < bytes.len() {
                let c = bytes[i] as char;
                if c.is_ascii_alphanumeric() || c == '_' {
                    i += 1;
                } else {
                    break;
                }
            }
            out.push((start, &code[start..i]));
        } else {
            i += 1;
        }
    }
    out
}
