//! R1 — decode-path panic-freedom.
//!
//! The paper's §5 trichotomy (corrected / clean-error / never-silent)
//! is a statement about *every* outcome of decoding attacker-shaped
//! bytes; a single `unwrap` on a hostile length turns the guaranteed
//! clean error into a process abort. In the untrusted-input modules
//! ([`crate::config::DECODE_SCOPES`]) non-test code may not contain
//! panicking macros, `unwrap`/`expect`, or direct `ident[...]` indexing
//! of the configured untrusted buffers. `debug_assert*` stays legal: it
//! compiles out of release builds, which is what the trichotomy gate
//! (mode-C campaigns) runs.

use crate::config;
use crate::lexer::SourceFile;
use crate::rules::{idents, word_start, Allows, Finding};

/// Forbidden panic tokens: (pattern, what, fix hint).
const PANIC_TOKENS: &[(&str, &str, &str)] = &[
    (
        ".unwrap(",
        "unwrap() in untrusted-input decode code",
        "return a clean Error::Format/CrashEquivalent instead (ok_or_else, \
         or a length-checked helper)",
    ),
    (
        ".expect(",
        "expect() in untrusted-input decode code",
        "return a clean Error instead — the message belongs in the error, \
         not a panic",
    ),
    (
        "panic!",
        "panic! in untrusted-input decode code",
        "return a clean Error; panicking on hostile bytes breaks the \
         never-silent trichotomy",
    ),
    (
        "unreachable!",
        "unreachable! in untrusted-input decode code",
        "return Error::CrashEquivalent — corrupt input can reach \
         'unreachable' arms",
    ),
    (
        "todo!",
        "todo! in untrusted-input decode code",
        "finish the path or return a clean Error",
    ),
    (
        "unimplemented!",
        "unimplemented! in untrusted-input decode code",
        "finish the path or return a clean Error",
    ),
    (
        "assert!",
        "assert! in untrusted-input decode code",
        "convert to an `if … { return Err(…) }` guard (or debug_assert! if \
         the condition is an internal invariant)",
    ),
    (
        "assert_eq!",
        "assert_eq! in untrusted-input decode code",
        "convert to an `if … { return Err(…) }` guard (or debug_assert_eq!)",
    ),
    (
        "assert_ne!",
        "assert_ne! in untrusted-input decode code",
        "convert to an `if … { return Err(…) }` guard (or debug_assert_ne!)",
    ),
];

/// Run R1 over one file.
pub fn run(file: &SourceFile, allows: &mut Allows, out: &mut Vec<Finding>) {
    let Some(scope) = config::scope_for(&file.rel_path) else {
        return;
    };
    for line in &file.lines {
        if line.in_test {
            continue;
        }
        if let Some(fns) = scope.r1_fns {
            match &line.fn_name {
                Some(n) if fns.contains(&n.as_str()) => {}
                _ => continue,
            }
        }
        let code = &line.code;
        for &(pat, what, hint) in PANIC_TOKENS {
            let mut from = 0;
            while let Some(off) = code[from..].find(pat) {
                let at = from + off;
                from = at + pat.len();
                if !word_start(code, at, pat) {
                    continue;
                }
                if allows.suppress("r1", line.number) {
                    continue;
                }
                out.push(Finding {
                    rule: "r1",
                    file: file.rel_path.clone(),
                    line: line.number,
                    message: what.to_string(),
                    hint: hint.to_string(),
                });
            }
        }
        // direct indexing of untrusted buffers: `ident[` with ident in the
        // module's untrusted set
        for (off, id) in idents(code) {
            if !scope.untrusted.contains(&id) {
                continue;
            }
            let end = off + id.len();
            if code.as_bytes().get(end) != Some(&b'[') {
                continue;
            }
            if allows.suppress("r1", line.number) {
                continue;
            }
            out.push(Finding {
                rule: "r1",
                file: file.rel_path.clone(),
                line: line.number,
                message: format!(
                    "direct `{id}[…]` index on an untrusted buffer"
                ),
                hint: "use .get()/.get_mut() with a clean error (or a \
                       bounds-checked cursor); annotate structurally \
                       guaranteed sites with ftlint::allow(r1, \"…\")"
                    .to_string(),
            });
        }
    }
}
