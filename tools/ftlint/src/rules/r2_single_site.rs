//! R2 — single-site architecture invariants.
//!
//! Several resilience claims in this repo are of the form "there is
//! exactly one place that does X" (one pipeline spawner trio, one
//! re-execution counter fold, one Algorithm-2 verify loop). Those used to
//! be grep-provable by hand; this rule counts the pattern occurrences in
//! non-test code per file and compares them against the exact allowlist
//! in [`crate::config::SINGLE_SITES`].
//!
//! There is deliberately NO `ftlint::allow` escape for R2: the audited
//! way to move or add a site is editing the allowlist in
//! `tools/ftlint/src/config.rs`, so the reviewer sees the invariant
//! change in that file's diff.

use crate::config;
use crate::lexer::SourceFile;
use crate::rules::{Allows, Finding};

/// Run R2 over one file.
pub fn run(file: &SourceFile, _allows: &mut Allows, out: &mut Vec<Finding>) {
    for site in config::SINGLE_SITES {
        let hits: Vec<usize> = file
            .lines
            .iter()
            .filter(|l| !l.in_test && l.code.contains(site.pattern))
            .map(|l| l.number)
            .collect();
        let allowed = site
            .allowed
            .iter()
            .find(|(f, _)| *f == file.rel_path)
            .map(|(_, n)| *n)
            .unwrap_or(0);
        if hits.len() > allowed {
            for &line in &hits[allowed..] {
                out.push(Finding {
                    rule: "r2",
                    file: file.rel_path.clone(),
                    line,
                    message: format!(
                        "`{}` site #{} of {} — allowlist permits {} in this \
                         file ({})",
                        site.pattern,
                        hits.iter().position(|&l| l == line).map(|p| p + 1).unwrap_or(0),
                        hits.len(),
                        allowed,
                        site.name,
                    ),
                    hint: format!(
                        "{} — or, if the architecture legitimately moved, \
                         update SINGLE_SITES in tools/ftlint/src/config.rs",
                        site.hint
                    ),
                });
            }
        } else if hits.len() < allowed {
            out.push(Finding {
                rule: "r2",
                file: file.rel_path.clone(),
                line: hits.first().copied().unwrap_or(1),
                message: format!(
                    "`{}` expected exactly {} non-test site(s) here, found \
                     {} — the {} allowlist is stale",
                    site.pattern,
                    allowed,
                    hits.len(),
                    site.name,
                ),
                hint: "update SINGLE_SITES in tools/ftlint/src/config.rs to \
                       match where the invariant actually lives"
                    .to_string(),
            });
        }
    }
}
