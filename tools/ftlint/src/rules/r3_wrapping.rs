//! R3 — checksum algebra must be explicitly wrapping.
//!
//! The additive fault-tolerance checksums (paper §4.2) are mod-2^64
//! homomorphisms: `verify` compares accumulators that legitimately wrap.
//! A bare `+`/`-`/`*` on an accumulator is correct in release builds but
//! aborts in debug builds on overflow — which means debug-mode fault
//! campaigns would crash where release mode silently works, hiding the
//! exact SDC-detection paths we test. In `ft/checksum.rs` every
//! accumulator operation must therefore be `wrapping_add` /
//! `wrapping_sub` / `wrapping_mul`, and this rule flags bare operators
//! adjacent to the known accumulator identifiers.

use crate::config;
use crate::lexer::SourceFile;
use crate::rules::{idents, Allows, Finding};

/// Run R3 over one file.
pub fn run(file: &SourceFile, allows: &mut Allows, out: &mut Vec<Finding>) {
    if file.rel_path != config::CHECKSUM_FILE {
        return;
    }
    for line in &file.lines {
        if line.in_test {
            continue;
        }
        let code = &line.code;
        let bytes = code.as_bytes();
        for (off, id) in idents(code) {
            if !config::CHECKSUM_ACCUMULATORS.contains(&id) {
                continue;
            }
            let mut flagged = false;
            // operator directly after the accumulator: `sum + x`, `sum -= x`,
            // `sum * x` — always binary arithmetic (or compound assignment)
            if let Some(c) = after_nonspace(bytes, off + id.len()) {
                if matches!(c, b'+' | b'-' | b'*') {
                    flagged = true;
                }
            }
            // operator directly before: binary only when the token before the
            // operator ends a value (ident/`)`/`]`); otherwise it is unary
            // minus or a deref and not arithmetic on the accumulator
            if !flagged {
                if let Some(op_at) = before_nonspace(bytes, off) {
                    if matches!(bytes[op_at], b'+' | b'-' | b'*') {
                        if let Some(prev_at) = before_nonspace(bytes, op_at) {
                            let p = bytes[prev_at];
                            if p.is_ascii_alphanumeric()
                                || p == b'_'
                                || p == b')'
                                || p == b']'
                            {
                                flagged = true;
                            }
                        }
                    }
                }
            }
            if !flagged || allows.suppress("r3", line.number) {
                continue;
            }
            out.push(Finding {
                rule: "r3",
                file: file.rel_path.clone(),
                line: line.number,
                message: format!(
                    "bare arithmetic on checksum accumulator `{id}`"
                ),
                hint: "use wrapping_add/wrapping_sub/wrapping_mul — the \
                       mod-2^64 homomorphism must behave identically in \
                       debug and release builds"
                    .to_string(),
            });
        }
    }
}

/// First non-space byte at or after `i`, as a char.
fn after_nonspace(bytes: &[u8], i: usize) -> Option<u8> {
    bytes[i.min(bytes.len())..]
        .iter()
        .copied()
        .find(|b| !b.is_ascii_whitespace())
}

/// Index of the last non-space byte strictly before `i`.
fn before_nonspace(bytes: &[u8], i: usize) -> Option<usize> {
    (0..i.min(bytes.len())).rev().find(|&j| !bytes[j].is_ascii_whitespace())
}
