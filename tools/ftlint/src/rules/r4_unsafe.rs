//! R4 — unsafe inventory.
//!
//! The crate's safety story is "no `unsafe` anywhere, enforced at the
//! root by `#![forbid(unsafe_code)]`", with one pre-approved future
//! carve-out: `io/posix.rs` (O_DIRECT / mmap style I/O is the only
//! plausible need). This rule (a) meta-checks that `lib.rs` still
//! carries the forbid attribute, (b) flags any `unsafe` token outside
//! the carve-out file, and (c) inside the carve-out requires a
//! `// SAFETY:` comment on the same line or in the contiguous comment
//! block directly above (however long the justification runs).
//!
//! No `ftlint::allow` escape: the only audited path for new unsafe is
//! moving it into the carve-out file (and softening the crate attribute
//! from `forbid` to `deny` + per-module `allow`, as documented there).

use crate::config;
use crate::lexer::SourceFile;
use crate::rules::{word_start, Allows, Finding};

/// Run R4 over one file.
pub fn run(file: &SourceFile, _allows: &mut Allows, out: &mut Vec<Finding>) {
    if file.rel_path == "lib.rs"
        && !file
            .lines
            .iter()
            .any(|l| l.code.contains(config::FORBID_UNSAFE_ATTR))
    {
        out.push(Finding {
            rule: "r4",
            file: file.rel_path.clone(),
            line: 1,
            message: format!(
                "crate root lost its `{}` attribute",
                config::FORBID_UNSAFE_ATTR
            ),
            hint: "restore the attribute; if unsafe is genuinely needed, \
                   follow the deny-softening recipe documented in io/posix.rs"
                .to_string(),
        });
    }

    let in_carveout = file.rel_path == config::UNSAFE_ALLOWED_FILE;
    for (li, line) in file.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let code = &line.code;
        let mut from = 0;
        while let Some(off) = code[from..].find("unsafe") {
            let at = from + off;
            from = at + "unsafe".len();
            // whole-word check on both sides
            if !word_start(code, at, "unsafe") {
                continue;
            }
            if code
                .as_bytes()
                .get(at + "unsafe".len())
                .is_some_and(|&b| b.is_ascii_alphanumeric() || b == b'_')
            {
                continue;
            }
            if !in_carveout {
                out.push(Finding {
                    rule: "r4",
                    file: file.rel_path.clone(),
                    line: line.number,
                    message: "`unsafe` outside the io/posix.rs carve-out"
                        .to_string(),
                    hint: "the crate is #![forbid(unsafe_code)]; move the \
                           code behind a safe abstraction, or (last resort) \
                           into io/posix.rs with a SAFETY: comment"
                        .to_string(),
                });
                continue;
            }
            // carve-out: demand a SAFETY: justification on the unsafe
            // line or in the contiguous comment block directly above it
            let mut justified = line.comment.contains("SAFETY:");
            let mut j = li;
            while !justified && j > 0 {
                j -= 1;
                let prev = &file.lines[j];
                if !prev.code.trim().is_empty() || prev.comment.is_empty() {
                    break;
                }
                justified = prev.comment.contains("SAFETY:");
            }
            if !justified {
                out.push(Finding {
                    rule: "r4",
                    file: file.rel_path.clone(),
                    line: line.number,
                    message: "`unsafe` in io/posix.rs without a // SAFETY: \
                              comment"
                        .to_string(),
                    hint: "write `// SAFETY: <why every precondition holds>` \
                           on the unsafe line or directly above it"
                        .to_string(),
                });
            }
        }
    }
}
