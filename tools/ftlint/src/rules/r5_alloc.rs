//! R5 — guarded allocation in decode modules.
//!
//! A hostile header that survives parsing long enough to reach an
//! allocation site can request absurd lengths (`vec![0; 2^60]`) and take
//! the process down by OOM — a crash-equivalent outcome the paper's
//! trichotomy forbids just as much as a panic. In the decode scopes,
//! allocation lengths must therefore come from *validated* quantities:
//! `.len()` of an already-bounds-checked slice, literal sizes, or
//! `MAX_*`-style constants (which is what the header validators clamp
//! against). Anything else — a bare variable that might trace back to raw
//! header bytes — is flagged and must either be rewritten or carry an
//! audited `ftlint::allow(r5, "…")` stating why the value is clamped.

use crate::config;
use crate::lexer::SourceFile;
use crate::rules::{idents, Allows, Finding};

/// Allocation patterns: (needle, opening bracket, which top-level piece of
/// the bracketed text is the length).
const ALLOC_SITES: &[(&str, char, LenPos)] = &[
    ("with_capacity(", '(', LenPos::Whole),
    (".resize(", '(', LenPos::FirstArg),
    ("vec![", '[', LenPos::AfterSemi),
];

#[derive(Clone, Copy)]
enum LenPos {
    /// The whole bracketed text is the length.
    Whole,
    /// Text before the first top-level `,`.
    FirstArg,
    /// Text after the top-level `;` (none → fixed-size literal list, safe).
    AfterSemi,
}

/// Run R5 over one file.
pub fn run(file: &SourceFile, allows: &mut Allows, out: &mut Vec<Finding>) {
    let Some(scope) = config::scope_for(&file.rel_path) else {
        return;
    };
    let fns = scope.r5_fns.or(scope.r1_fns);
    for (li, line) in file.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        if let Some(fns) = fns {
            match &line.fn_name {
                Some(n) if fns.contains(&n.as_str()) => {}
                _ => continue,
            }
        }
        let code = &line.code;
        for &(needle, open, pos) in ALLOC_SITES {
            let mut from = 0;
            while let Some(off) = code[from..].find(needle) {
                let at = from + off;
                from = at + needle.len();
                // left boundary: `with_capacity` must not be the tail of a
                // longer identifier (patterns starting with `.` carry their
                // own boundary — the dot — and are preceded by a receiver)
                if !needle.starts_with('.') && at > 0 {
                    let prev = code.as_bytes()[at - 1];
                    if prev.is_ascii_alphanumeric() || prev == b'_' {
                        continue;
                    }
                }
                let Some(inner) = capture(file, li, at + needle.len(), open)
                else {
                    continue; // unbalanced within the lookahead window
                };
                let len_expr = match pos {
                    LenPos::Whole => inner.clone(),
                    LenPos::FirstArg => top_level_split(&inner, ',')
                        .map(|(a, _)| a.to_string())
                        .unwrap_or(inner.clone()),
                    LenPos::AfterSemi => {
                        match top_level_split(&inner, ';') {
                            Some((_, b)) => b.to_string(),
                            None => continue, // literal list, fixed size
                        }
                    }
                };
                if is_safe_len(&len_expr) {
                    continue;
                }
                if allows.suppress("r5", line.number) {
                    continue;
                }
                out.push(Finding {
                    rule: "r5",
                    file: file.rel_path.clone(),
                    line: line.number,
                    message: format!(
                        "decode-path allocation sized by unvalidated \
                         expression `{}`",
                        len_expr.trim()
                    ),
                    hint: "size decode allocations from .len() of a \
                           bounds-checked slice, a literal, or a MAX_* \
                           clamp constant; annotate audited clamped sites \
                           with ftlint::allow(r5, \"…\")"
                        .to_string(),
                });
            }
        }
    }
}

/// Capture the bracketed text starting right after the opener at
/// (`li`, `start_col`), balancing across at most 10 lines. Strings are
/// already blanked, so every bracket is structural.
fn capture(file: &SourceFile, li: usize, start_col: usize, open: char) -> Option<String> {
    let close = match open {
        '(' => ')',
        '[' => ']',
        _ => return None,
    };
    let mut depth = 0i32;
    let mut text = String::new();
    for (k, line) in file.lines.iter().enumerate().skip(li).take(10) {
        let code = &line.code;
        let begin = if k == li { start_col.min(code.len()) } else { 0 };
        for c in code[begin..].chars() {
            match c {
                '(' | '[' | '{' => depth += 1,
                ')' | ']' | '}' => {
                    if depth == 0 && c == close {
                        return Some(text);
                    }
                    depth -= 1;
                }
                _ => {}
            }
            text.push(c);
        }
        text.push(' ');
    }
    None
}

/// Split at the first top-level occurrence of `sep`.
fn top_level_split(s: &str, sep: char) -> Option<(&str, &str)> {
    let mut depth = 0i32;
    for (i, c) in s.char_indices() {
        match c {
            '(' | '[' | '{' => depth += 1,
            ')' | ']' | '}' => depth -= 1,
            _ if c == sep && depth == 0 => {
                return Some((&s[..i], &s[i + c.len_utf8()..]));
            }
            _ => {}
        }
    }
    None
}

/// Identifiers/casts that never make a length "unvalidated".
const NEUTRAL_IDENTS: &[&str] = &[
    "as", "usize", "u8", "u16", "u32", "u64", "u128", "i8", "i16", "i32",
    "i64", "i128", "f32", "f64", "self",
];

/// The validated-length heuristic: `.len()` of something, pure literals,
/// or SCREAMING_CASE constants.
fn is_safe_len(expr: &str) -> bool {
    if expr.contains(".len(") {
        return true;
    }
    let bytes = expr.as_bytes();
    for (off, id) in idents(expr) {
        if off > 0 {
            let prev = bytes[off - 1];
            // `.ident` is a field/method on some receiver; a digit prefix
            // means this "ident" is the suffix of a numeric literal (0u8,
            // 0xFF)
            if prev == b'.' || prev.is_ascii_digit() {
                continue;
            }
        }
        if NEUTRAL_IDENTS.contains(&id) || is_screaming(id) {
            continue;
        }
        return false;
    }
    true
}

/// `MAX_SECTION`, `LUT_BITS`, … — consts by Rust convention.
fn is_screaming(id: &str) -> bool {
    id.chars().any(|c| c.is_ascii_uppercase())
        && id
            .chars()
            .all(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_')
}
