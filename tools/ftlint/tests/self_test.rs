//! ftlint self-tests: every rule must trip on its bad fixture, stay
//! silent on its good fixture, the escape hatch must be audited, and —
//! the point of the whole tool — the real `rust/src` tree must be clean.

use std::fs;
use std::path::Path;

use ftlint::{lint_source, Finding};

/// Load a fixture and lint it under a pretend tree-relative path so the
/// scope tables in `config.rs` apply.
fn lint_fixture(name: &str, pretend_path: &str) -> Vec<Finding> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("fixtures")
        .join(name);
    let content = fs::read_to_string(&p)
        .unwrap_or_else(|e| panic!("fixture {}: {e}", p.display()));
    lint_source(pretend_path, &content)
}

fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule).collect()
}

// --- R1: decode-path panic-freedom -----------------------------------------

#[test]
fn r1_bad_trips_on_every_token_class() {
    let f = lint_fixture("r1_bad.rs", "compressor/format.rs");
    let msgs: Vec<&str> = f.iter().map(|x| x.message.as_str()).collect();
    assert!(f.iter().all(|x| x.rule == "r1"), "only r1 expected: {f:?}");
    assert!(
        msgs.iter().any(|m| m.contains("unwrap()")),
        "unwrap missed: {msgs:?}"
    );
    assert!(msgs.iter().any(|m| m.contains("panic!")));
    assert!(msgs.iter().any(|m| m.contains("unreachable!")));
    assert!(msgs.iter().any(|m| m.contains("assert_eq!")));
    assert!(
        msgs.iter().any(|m| m.contains("`data[…]`")),
        "untrusted index missed: {msgs:?}"
    );
    // every finding carries a location and a hint
    assert!(f.iter().all(|x| x.line > 0 && !x.hint.is_empty()));
}

#[test]
fn r1_good_is_clean() {
    let f = lint_fixture("r1_good.rs", "compressor/format.rs");
    assert!(f.is_empty(), "expected clean, got {f:?}");
}

// --- R2: single-site invariants --------------------------------------------

#[test]
fn r2_bad_trips_outside_allowlist() {
    let f = lint_fixture("r2_bad.rs", "compressor/rogue.rs");
    assert_eq!(rules_of(&f), vec!["r2"], "{f:?}");
    assert!(f[0].message.contains("thread::scope"));
}

#[test]
fn r2_good_exact_count_is_clean() {
    let f = lint_fixture("r2_good.rs", "coordinator/pipeline.rs");
    assert!(f.is_empty(), "expected clean, got {f:?}");
}

#[test]
fn r2_stale_allowlist_is_reported() {
    // pipeline.rs is granted one thread::scope; a file with zero trips the
    // under-count (stale allowlist) arm
    let f = lint_source("coordinator/pipeline.rs", "pub fn quiet() {}\n");
    assert_eq!(rules_of(&f), vec!["r2"], "{f:?}");
    assert!(f[0].message.contains("stale"));
}

// --- R3: wrapping checksum algebra -----------------------------------------

#[test]
fn r3_bad_trips_on_bare_arithmetic() {
    let f = lint_fixture("r3_bad.rs", "ft/checksum.rs");
    assert!(!f.is_empty() && f.iter().all(|x| x.rule == "r3"), "{f:?}");
    // `sum += x`, `sum - delta`, and the binary-operand position of delta
    assert!(f.len() >= 2, "compound and binary both expected: {f:?}");
}

#[test]
fn r3_good_wrapping_is_clean() {
    let f = lint_fixture("r3_good.rs", "ft/checksum.rs");
    assert!(f.is_empty(), "expected clean, got {f:?}");
}

// --- R4: unsafe inventory ---------------------------------------------------

#[test]
fn r4_bad_trips_outside_carveout() {
    let f = lint_fixture("r4_bad.rs", "util/rogue.rs");
    assert_eq!(rules_of(&f), vec!["r4"], "{f:?}");
    assert!(f[0].message.contains("carve-out"));
}

#[test]
fn r4_good_safety_comment_in_carveout_is_clean() {
    let f = lint_fixture("r4_good.rs", "io/posix.rs");
    assert!(f.is_empty(), "expected clean, got {f:?}");
}

#[test]
fn r4_carveout_without_safety_comment_trips() {
    let src = "pub fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
    let f = lint_source("io/posix.rs", src);
    assert_eq!(rules_of(&f), vec!["r4"], "{f:?}");
    assert!(f[0].message.contains("SAFETY"));
}

#[test]
fn r4_crate_root_must_keep_forbid() {
    let f = lint_source("lib.rs", "pub mod compressor;\n");
    assert!(
        f.iter().any(|x| x.rule == "r4" && x.message.contains("forbid")),
        "{f:?}"
    );
    let ok = lint_source("lib.rs", "#![forbid(unsafe_code)]\npub mod compressor;\n");
    assert!(ok.is_empty(), "{ok:?}");
}

// --- R5: guarded allocation -------------------------------------------------

#[test]
fn r5_bad_trips_on_unvalidated_lengths() {
    let f = lint_fixture("r5_bad.rs", "compressor/format.rs");
    assert!(!f.is_empty() && f.iter().all(|x| x.rule == "r5"), "{f:?}");
    assert!(f.len() >= 2, "with_capacity and vec![..; n] both: {f:?}");
}

#[test]
fn r5_good_validated_lengths_are_clean() {
    let f = lint_fixture("r5_good.rs", "compressor/format.rs");
    assert!(f.is_empty(), "expected clean, got {f:?}");
}

// --- the kernel decode scope (R1 + R5 share the scoped fn list) -------------

#[test]
fn kernel_bad_trips_r1_and_r5_inside_scoped_fns() {
    let f = lint_fixture("kernel_bad.rs", "compressor/kernel.rs");
    let rules = rules_of(&f);
    assert!(rules.contains(&"r1"), "{f:?}");
    assert!(rules.contains(&"r5"), "{f:?}");
    let msgs: Vec<&str> = f.iter().map(|x| x.message.as_str()).collect();
    assert!(
        msgs.iter().any(|m| m.contains("`body[…]`")),
        "untrusted body index missed: {msgs:?}"
    );
    assert!(msgs.iter().any(|m| m.contains("panic!")), "{msgs:?}");
    assert!(msgs.iter().any(|m| m.contains("unwrap()")), "{msgs:?}");
    assert!(msgs.iter().any(|m| m.contains("assert!")), "{msgs:?}");
    assert!(
        msgs.iter().any(|m| m.contains("unvalidated")),
        "r5 alloc missed: {msgs:?}"
    );
}

#[test]
fn kernel_good_iterator_traversal_is_clean() {
    let f = lint_fixture("kernel_good.rs", "compressor/kernel.rs");
    assert!(f.is_empty(), "expected clean, got {f:?}");
}

#[test]
fn kernel_scope_excludes_the_pack_side() {
    // same token class as kernel_bad's, but inside a fn the scope list
    // doesn't name — the compress side takes trusted input
    let src = "pub extern \"C\" fn ftsz_kernel_pack_bytes(codes: &[u32]) -> u32 {\n\
               \x20   codes.first().copied().unwrap()\n}\n";
    let f = lint_source("compressor/kernel.rs", src);
    assert!(f.is_empty(), "pack side must be out of scope: {f:?}");
}

#[test]
fn xsz_fill_from_codes_is_in_decode_scope() {
    // the shared fixed-point fill joined decode_block in the xsz scope list
    let src = "fn fill_from_codes(pool: &[f32]) -> f32 {\n\
               \x20   pool.first().copied().unwrap()\n}\n";
    let f = lint_source("compressor/xsz.rs", src);
    assert_eq!(rules_of(&f), vec!["r1"], "{f:?}");
}

// --- the serve wire surface (store/protocol scope) ---------------------------

#[test]
fn store_protocol_bad_trips_r1_and_r5() {
    let f = lint_fixture("store_bad.rs", "compressor/store/protocol.rs");
    let rules = rules_of(&f);
    assert!(rules.contains(&"r1"), "{f:?}");
    assert!(rules.contains(&"r5"), "{f:?}");
    let msgs: Vec<&str> = f.iter().map(|x| x.message.as_str()).collect();
    assert!(
        msgs.iter().any(|m| m.contains("`parts[…]`")),
        "untrusted field index missed: {msgs:?}"
    );
    assert!(
        msgs.iter().any(|m| m.contains("`line[…]`")),
        "untrusted line index missed: {msgs:?}"
    );
    assert!(msgs.iter().any(|m| m.contains("panic!")), "{msgs:?}");
    assert!(msgs.iter().any(|m| m.contains("unreachable!")), "{msgs:?}");
    assert!(msgs.iter().any(|m| m.contains("unwrap()")), "{msgs:?}");
    assert!(
        msgs.iter().any(|m| m.contains("unvalidated")),
        "client-sized allocation missed: {msgs:?}"
    );
}

#[test]
fn store_protocol_good_is_clean() {
    let f = lint_fixture("store_good.rs", "compressor/store/protocol.rs");
    assert!(f.is_empty(), "expected clean, got {f:?}");
}

#[test]
fn store_protocol_scope_excludes_the_writer_side() {
    // response *rendering* consumes trusted server state; only the
    // request/response parsers face the wire
    let src = "pub fn ok_header(values: usize) -> String {\n\
               \x20   format!(\"OK {}\", values.checked_mul(4).unwrap())\n}\n";
    let f = lint_source("compressor/store/protocol.rs", src);
    assert!(f.is_empty(), "writer side must be out of scope: {f:?}");
}

// --- the escape hatch is itself audited ------------------------------------

#[test]
fn allow_with_empty_reason_is_malformed() {
    let src = "// ftlint::allow(r1, \"\")\npub fn f() {}\n";
    let f = lint_source("compressor/format.rs", src);
    assert!(
        f.iter().any(|x| x.rule == "allow" && x.message.contains("malformed")),
        "{f:?}"
    );
}

#[test]
fn allow_without_reason_is_malformed() {
    let src = "pub fn f() {} // ftlint::allow(r1)\n";
    let f = lint_source("compressor/format.rs", src);
    assert!(f.iter().any(|x| x.rule == "allow"), "{f:?}");
}

#[test]
fn stale_allow_is_reported() {
    let src = "pub fn parse() -> u32 {\n    // ftlint::allow(r1, \"suppresses nothing\")\n\
               \x20   7\n}\n";
    let f = lint_source("compressor/format.rs", src);
    assert!(
        f.iter().any(|x| x.rule == "allow" && x.message.contains("stale")),
        "{f:?}"
    );
}

// --- the real tree ----------------------------------------------------------

#[test]
fn real_rust_src_tree_is_clean() {
    let root = ftlint::default_root();
    let findings = ftlint::lint_tree(&root).expect("lint rust/src");
    assert!(
        findings.is_empty(),
        "rust/src has {} lint finding(s):\n{}",
        findings.len(),
        findings
            .iter()
            .map(|f| format!("  {}:{}: [{}] {}", f.file, f.line, f.rule, f.message))
            .collect::<Vec<_>>()
            .join("\n")
    );
}
